//! JIT partitioner: layers → chip-sized chunks (paper §II-D "Hardware
//! Resources" / "Data-Flow Graph Execution").
//!
//! "Individual layers are partitioned into chip-sized chunks and executed
//! either in parallel, serially, or in the appropriate mixture needed to fit
//! on the available hardware resources."
//!
//! A linear layer of shape `in_dim × out_dim` is tiled into chunks of at
//! most `K_LOGICAL` logical inputs × `N_COLS` columns.  Chunks sharing the
//! same input tile can run on different array halves *in parallel*; chunks
//! along the input dimension run *serially* and their partial sums are added
//! digitally by the SIMD CPUs (exactly how fc1's two blocks work in Fig 6).

use crate::asic::consts as c;

/// One chip-sized chunk of a layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Chunk {
    /// Input rows `[in_start, in_end)` of the logical layer.
    pub in_start: usize,
    pub in_end: usize,
    /// Output columns `[out_start, out_end)`.
    pub out_start: usize,
    pub out_end: usize,
    /// Sequential step this chunk runs in (chunks with the same step can
    /// execute in parallel on different halves / chips).
    pub step: usize,
    /// Which partial-sum group the chunk contributes to (same group ⇒
    /// digital accumulation by the SIMD CPU).
    pub psum_group: usize,
}

impl Chunk {
    pub fn in_len(&self) -> usize {
        self.in_end - self.in_start
    }

    pub fn out_len(&self) -> usize {
        self.out_end - self.out_start
    }
}

/// Execution plan for one linear layer.
#[derive(Debug, Clone)]
pub struct Plan {
    pub in_dim: usize,
    pub out_dim: usize,
    pub chunks: Vec<Chunk>,
    /// Number of sequential steps (given `parallel_halves` usable halves).
    pub steps: usize,
}

/// Partition an `in_dim × out_dim` layer onto hardware with
/// `parallel_halves` array halves available per step.
pub fn partition(in_dim: usize, out_dim: usize, parallel_halves: usize) -> Plan {
    assert!(in_dim > 0 && out_dim > 0 && parallel_halves > 0);
    let in_tiles = in_dim.div_ceil(c::K_LOGICAL);
    let out_tiles = out_dim.div_ceil(c::N_COLS);
    let mut chunks = Vec::with_capacity(in_tiles * out_tiles);
    let _ = in_tiles;
    // Chunk (i, o): input tile i, output tile o.  All input tiles of one
    // output tile form one partial-sum group.
    let mut slot = 0usize; // round-robin over halves per step
    for o in 0..out_tiles {
        for i in 0..in_tiles {
            let step = slot / parallel_halves;
            chunks.push(Chunk {
                in_start: i * c::K_LOGICAL,
                in_end: ((i + 1) * c::K_LOGICAL).min(in_dim),
                out_start: o * c::N_COLS,
                out_end: ((o + 1) * c::N_COLS).min(out_dim),
                step,
                psum_group: o, // one partial-sum group per output tile
            });
            slot += 1;
        }
    }
    let steps = chunks.iter().map(|ch| ch.step).max().unwrap_or(0) + 1;
    Plan { in_dim, out_dim, chunks, steps }
}

impl Plan {
    /// Execute the plan against a dense f32 weight matrix + input vector
    /// (reference executor used for equivalence tests and the mock engine;
    /// the hardware engine maps each chunk onto an array pass instead).
    pub fn execute_dense(&self, w: &[f32], x: &[f32]) -> Vec<f32> {
        assert_eq!(w.len(), self.in_dim * self.out_dim);
        assert_eq!(x.len(), self.in_dim);
        let mut out = vec![0.0f32; self.out_dim];
        for chv in &self.chunks {
            for col in chv.out_start..chv.out_end {
                let mut acc = 0.0f32;
                for row in chv.in_start..chv.in_end {
                    acc += x[row] * w[row * self.out_dim + col];
                }
                out[col] += acc; // digital partial-sum accumulation
            }
        }
        out
    }

    /// Validate the structural invariants (used by the property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        // 1. Full coverage without overlap: every (row, col) in exactly
        //    one chunk.
        let mut cover = vec![0u8; self.in_dim * self.out_dim];
        for ch in &self.chunks {
            if ch.in_len() > c::K_LOGICAL {
                return Err(format!("chunk exceeds K_LOGICAL: {ch:?}"));
            }
            if ch.out_len() > c::N_COLS {
                return Err(format!("chunk exceeds N_COLS: {ch:?}"));
            }
            for r in ch.in_start..ch.in_end {
                for cl in ch.out_start..ch.out_end {
                    let slot = &mut cover[r * self.out_dim + cl];
                    if *slot != 0 {
                        return Err(format!("overlap at ({r},{cl})"));
                    }
                    *slot = 1;
                }
            }
        }
        if cover.iter().any(|&v| v == 0) {
            return Err("incomplete coverage".into());
        }
        // 2. Chunks of one psum group span distinct input tiles.
        // 3. Steps are dense 0..steps.
        let max_step = self.chunks.iter().map(|c| c.step).max().unwrap_or(0);
        if max_step + 1 != self.steps {
            return Err("steps not dense".into());
        }
        Ok(())
    }

    /// Array passes (integration cycles) the plan costs.
    pub fn passes(&self) -> usize {
        self.chunks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck;

    #[test]
    fn single_chip_layer_is_one_chunk() {
        let p = partition(c::K_LOGICAL, c::N_COLS, 2);
        assert_eq!(p.chunks.len(), 1);
        assert_eq!(p.steps, 1);
        p.check_invariants().unwrap();
    }

    #[test]
    fn fc1_like_split() {
        // 256 inputs fit one half; 123 outputs fit: one chunk.
        let p = partition(256, 123, 2);
        assert_eq!(p.chunks.len(), 1);
        // A 512-input layer needs 2 input tiles -> 2 chunks, 1 psum group.
        let p = partition(512, 123, 2);
        assert_eq!(p.chunks.len(), 2);
        assert!(p.chunks.iter().all(|c| c.psum_group == 0));
        assert_eq!(p.steps, 1, "two halves -> parallel");
        p.check_invariants().unwrap();
    }

    #[test]
    fn large_layer_serialises() {
        // 1024 x 1024: 4 input tiles x 4 output tiles = 16 chunks; with 2
        // halves that is 8 sequential steps.
        let p = partition(1024, 1024, 2);
        assert_eq!(p.chunks.len(), 16);
        assert_eq!(p.steps, 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn ragged_dims_covered() {
        let p = partition(300, 400, 2);
        p.check_invariants().unwrap();
        assert_eq!(p.chunks.len(), 4);
    }

    #[test]
    fn dense_execution_matches_direct_matmul() {
        propcheck::check("partition_equiv", 20, 0xBEEF, |g| {
            let in_dim = g.usize_in(1, 700);
            let out_dim = g.usize_in(1, 600);
            let halves = g.usize_in(1, 4);
            let w = g.vec_f32(in_dim * out_dim, -2.0, 2.0);
            let x = g.vec_f32(in_dim, 0.0, 31.0);
            let plan = partition(in_dim, out_dim, halves);
            plan.check_invariants()?;
            let got = plan.execute_dense(&w, &x);
            for col in [0, out_dim / 2, out_dim - 1] {
                let want: f32 = (0..in_dim)
                    .map(|r| x[r] * w[r * out_dim + col])
                    .sum();
                let diff = (got[col] - want).abs();
                let tol = 1e-3 * want.abs().max(1.0);
                prop_assert!(
                    diff <= tol,
                    "col {col}: got {} want {want}",
                    got[col]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn invariants_catch_bad_plans() {
        let mut p = partition(256, 256, 1);
        p.chunks[0].in_end = 100; // break coverage
        assert!(p.check_invariants().is_err());
    }

    #[test]
    fn more_halves_fewer_steps() {
        let p1 = partition(1024, 512, 1);
        let p4 = partition(1024, 512, 4);
        assert!(p4.steps < p1.steps);
        assert_eq!(p1.passes(), p4.passes(), "same work, different schedule");
    }

    #[test]
    fn arbitrarily_large_models_supported() {
        // Paper §V: "rate-based stateless operation ... supports arbitrarily
        // large model sizes", limited only by memory.
        let p = partition(10_000, 4_000, 2);
        p.check_invariants().unwrap();
        assert_eq!(p.passes(), 40 * 16);
    }
}
