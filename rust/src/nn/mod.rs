//! Neural-network layer: trained model loading, logical→physical mapping,
//! data-flow graph + JIT partitioner (the hxtorch-equivalent, paper §II-D).

pub mod executor;
pub mod graph;
pub mod mapping;
pub mod partition;
pub mod weights;

// The executor's runner/scratch surface, re-exported flat: backends
// implement [`PassRunner`], hot paths hold a [`BatchScratch`] and drive
// the `_into` entry points (DESIGN.md §17).
pub use executor::{
    run_layer_batch_into, run_model_batch_flat, BatchScratch, NativeRunner,
    PassRunner,
};
