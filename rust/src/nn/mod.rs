//! Neural-network layer: trained model loading, logical→physical mapping,
//! data-flow graph + JIT partitioner (the hxtorch-equivalent, paper §II-D).

pub mod executor;
pub mod graph;
pub mod mapping;
pub mod partition;
pub mod weights;
