//! Logical → physical weight mapping (rust mirror of `model.pack_*`).
//!
//! Every network layer is packed into a 256×256 physical weight matrix for
//! one synapse-array half (paper Fig 6 right):
//!
//! * **conv**  — Toeplitz placement, the kernel replicated 32× across
//!   column groups (upper half).
//! * **fc1**   — two side-by-side 128-input column blocks sharing physical
//!   rows via synapse address matching (lower half, cols 0..246).
//! * **fc2**   — 123→10 on the lower half's right-most columns (246..256).
//!
//! The mappings must be bit-identical to the python versions: the exported
//! `weights.json` holds *logical* weights, and both sides pack them.

use crate::asic::consts as c;

/// Row-major `[K_LOGICAL][N_COLS]` physical matrix.
pub type PhysMatrix = Vec<f32>;

fn zeros() -> PhysMatrix {
    vec![0.0; c::K_LOGICAL * c::N_COLS]
}

#[inline]
fn at(m: &mut PhysMatrix, row: usize, col: usize) -> &mut f32 {
    &mut m[row * c::N_COLS + col]
}

/// conv weights `[C_OUT][C_IN][K]` → upper-half matrix.
pub fn pack_conv(wc: &[f32]) -> PhysMatrix {
    assert_eq!(
        wc.len(),
        c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL,
        "conv weight shape"
    );
    let idx = |o: usize, ch: usize, t: usize| {
        (o * c::ECG_CHANNELS + ch) * c::CONV_KERNEL + t
    };
    let mut m = zeros();
    for p in 0..c::CONV_POSITIONS {
        let start = p as isize * c::CONV_STRIDE as isize - c::CONV_PAD as isize;
        for o in 0..c::CONV_CHANNELS {
            let col = p * c::CONV_CHANNELS + o;
            for ch in 0..c::ECG_CHANNELS {
                for t in 0..c::CONV_KERNEL {
                    let ti = start + t as isize;
                    if ti >= 0 && (ti as usize) < c::POOLED_LEN {
                        let row = ch * c::POOLED_LEN + ti as usize;
                        *at(&mut m, row, col) = wc[idx(o, ch, t)];
                    }
                }
            }
        }
    }
    m
}

/// fc1 weights `[K_LOGICAL][FC1_OUT]` → lower-half matrix (two blocks).
pub fn pack_fc1(w1: &[f32]) -> PhysMatrix {
    assert_eq!(w1.len(), c::K_LOGICAL * c::FC1_OUT, "fc1 weight shape");
    let mut m = zeros();
    for r in 0..c::K_SIGNED {
        for j in 0..c::FC1_OUT {
            *at(&mut m, r, j) = w1[r * c::FC1_OUT + j];
        }
    }
    for r in c::K_SIGNED..c::K_LOGICAL {
        for j in 0..c::FC1_OUT {
            *at(&mut m, r, c::FC1_OUT + j) = w1[r * c::FC1_OUT + j];
        }
    }
    m
}

/// fc2 weights `[FC1_OUT][FC2_OUT]` → lower-half matrix (cols 246..256).
pub fn pack_fc2(w2: &[f32]) -> PhysMatrix {
    assert_eq!(w2.len(), c::FC1_OUT * c::FC2_OUT, "fc2 weight shape");
    let mut m = zeros();
    for r in 0..c::FC1_OUT {
        for j in 0..c::FC2_OUT {
            *at(&mut m, r, 2 * c::FC1_OUT + j) = w2[r * c::FC2_OUT + j];
        }
    }
    m
}

/// Recover the logical conv weights `[C_OUT][C_IN][K]` from a
/// Toeplitz-packed matrix (inverse of [`pack_conv`]).  Every interior
/// position carries a full copy of the kernel; position 4 is the first
/// one whose entire receptive field `start..start+K` lies inside
/// `0..POOLED_LEN` (start = 4·2 − 3 = 5), so each tap reads back from a
/// placed cell.
pub fn unpack_conv(m: &PhysMatrix) -> Vec<f32> {
    assert_eq!(m.len(), c::K_LOGICAL * c::N_COLS, "phys matrix shape");
    let p = 4usize;
    let start = p * c::CONV_STRIDE - c::CONV_PAD;
    debug_assert!(start + c::CONV_KERNEL <= c::POOLED_LEN);
    let mut wc =
        vec![0.0f32; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
    for o in 0..c::CONV_CHANNELS {
        let col = p * c::CONV_CHANNELS + o;
        for ch in 0..c::ECG_CHANNELS {
            for t in 0..c::CONV_KERNEL {
                let row = ch * c::POOLED_LEN + start + t;
                wc[(o * c::ECG_CHANNELS + ch) * c::CONV_KERNEL + t] =
                    m[row * c::N_COLS + col];
            }
        }
    }
    wc
}

/// Recover the logical fc1 weights `[K_LOGICAL][FC1_OUT]` (inverse of
/// [`pack_fc1`]'s two-block placement).
pub fn unpack_fc1(m: &PhysMatrix) -> Vec<f32> {
    assert_eq!(m.len(), c::K_LOGICAL * c::N_COLS, "phys matrix shape");
    let mut w1 = vec![0.0f32; c::K_LOGICAL * c::FC1_OUT];
    for r in 0..c::K_LOGICAL {
        let block = if r < c::K_SIGNED { 0 } else { c::FC1_OUT };
        for j in 0..c::FC1_OUT {
            w1[r * c::FC1_OUT + j] = m[r * c::N_COLS + block + j];
        }
    }
    w1
}

/// Recover the logical fc2 weights `[FC1_OUT][FC2_OUT]` (inverse of
/// [`pack_fc2`]'s right-most column block).
pub fn unpack_fc2(m: &PhysMatrix) -> Vec<f32> {
    assert_eq!(m.len(), c::K_LOGICAL * c::N_COLS, "phys matrix shape");
    let mut w2 = vec![0.0f32; c::FC1_OUT * c::FC2_OUT];
    for r in 0..c::FC1_OUT {
        for j in 0..c::FC2_OUT {
            w2[r * c::FC2_OUT + j] = m[r * c::N_COLS + 2 * c::FC1_OUT + j];
        }
    }
    w2
}

/// Convert a physical matrix to the i8 grid for the native array model.
pub fn to_i8(m: &PhysMatrix) -> Vec<i8> {
    m.iter()
        .map(|&w| (w as i32).clamp(-c::W_MAX, c::W_MAX) as i8)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn rand_w(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..len)
            .map(|_| (rng.below(127) as i32 - 63) as f32)
            .collect()
    }

    #[test]
    fn conv_toeplitz_structure() {
        let wc = rand_w(c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL, 1);
        let m = pack_conv(&wc);
        // Rows beyond MODEL_IN are empty.
        for r in c::MODEL_IN..c::K_LOGICAL {
            for col in 0..c::N_COLS {
                assert_eq!(m[r * c::N_COLS + col], 0.0);
            }
        }
        // Interior positions are shifted copies (paper: identical weight
        // arranged 32 times).
        let (p0, p1) = (4usize, 10usize);
        let shift = (p1 - p0) * c::CONV_STRIDE;
        for t in 0..(c::POOLED_LEN - shift) {
            let a = m[t * c::N_COLS + p0 * c::CONV_CHANNELS];
            let b = m[(t + shift) * c::N_COLS + p1 * c::CONV_CHANNELS];
            assert_eq!(a, b, "t={t}");
        }
    }

    #[test]
    fn conv_specific_tap() {
        let mut wc =
            vec![0.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        // o=3, ch=1, t=2 -> value 7
        wc[(3 * c::ECG_CHANNELS + 1) * c::CONV_KERNEL + 2] = 7.0;
        let m = pack_conv(&wc);
        let p = 5;
        let col = p * c::CONV_CHANNELS + 3;
        let ti = p * c::CONV_STRIDE - c::CONV_PAD + 2;
        let row = c::POOLED_LEN + ti;
        assert_eq!(m[row * c::N_COLS + col], 7.0);
    }

    #[test]
    fn fc1_blocks() {
        let w1 = rand_w(c::K_LOGICAL * c::FC1_OUT, 2);
        let m = pack_fc1(&w1);
        assert_eq!(m[0], w1[0]);
        // Block B: row 128 lands in cols 123..246.
        assert_eq!(
            m[c::K_SIGNED * c::N_COLS + c::FC1_OUT],
            w1[c::K_SIGNED * c::FC1_OUT]
        );
        // Cross blocks are zero.
        assert_eq!(m[0 * c::N_COLS + c::FC1_OUT + 1], 0.0);
        assert_eq!(m[c::K_SIGNED * c::N_COLS], 0.0);
        // fc2 columns empty.
        for r in 0..c::K_LOGICAL {
            for j in (2 * c::FC1_OUT)..c::N_COLS {
                assert_eq!(m[r * c::N_COLS + j], 0.0);
            }
        }
    }

    #[test]
    fn fc2_block() {
        let w2 = rand_w(c::FC1_OUT * c::FC2_OUT, 3);
        let m = pack_fc2(&w2);
        assert_eq!(m[2 * c::FC1_OUT], w2[0]);
        assert_eq!(
            m[5 * c::N_COLS + 2 * c::FC1_OUT + 3],
            w2[5 * c::FC2_OUT + 3]
        );
        for r in c::FC1_OUT..c::K_LOGICAL {
            for col in 0..c::N_COLS {
                assert_eq!(m[r * c::N_COLS + col], 0.0);
            }
        }
    }

    #[test]
    fn unpack_inverts_pack() {
        let wc = rand_w(c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL, 11);
        let w1 = rand_w(c::K_LOGICAL * c::FC1_OUT, 12);
        let w2 = rand_w(c::FC1_OUT * c::FC2_OUT, 13);
        assert_eq!(unpack_conv(&pack_conv(&wc)), wc);
        assert_eq!(unpack_fc1(&pack_fc1(&w1)), w1);
        assert_eq!(unpack_fc2(&pack_fc2(&w2)), w2);
    }

    #[test]
    fn to_i8_clamps() {
        let m = vec![100.0, -100.0, 5.0];
        assert_eq!(to_i8(&m), vec![63, -63, 5]);
    }
}
