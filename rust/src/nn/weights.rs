//! Trained-model loading: `weights.json` → packed physical matrices +
//! calibration + per-layer scales.

use std::path::Path;

use crate::asic::consts as c;
use crate::util::json::Json;

use super::mapping;

/// The trained ECG model in physical form, ready for the engine.
#[derive(Debug, Clone)]
pub struct TrainedModel {
    /// Physical matrices for the three passes (conv, fc1, fc2).
    pub pass_weights: [mapping::PhysMatrix; 3],
    /// Per-layer amplification (paper's right-shift configuration).
    pub scales: [f32; 3],
    /// Per-half calibration `[half][col]`.
    pub gain: [Vec<f32>; 2],
    pub offset: [Vec<f32>; 2],
    pub noise_sigma: f64,
    /// Training-time metrics recorded in the artifact.
    pub train_metrics: std::collections::BTreeMap<String, f64>,
}

impl TrainedModel {
    pub fn load(path: &Path) -> anyhow::Result<TrainedModel> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<TrainedModel> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("weights.json: {e}"))?;
        let format = j.req("format")?.as_str().unwrap_or("");
        anyhow::ensure!(
            format == "bss2-weights-v1",
            "unsupported weights format `{format}`"
        );

        let wc = j.req("wc")?.to_f32_vec()?;
        let w1 = j.req("w1")?.to_f32_vec()?;
        let w2 = j.req("w2")?.to_f32_vec()?;
        for (name, w, limit) in
            [("wc", &wc, c::W_MAX), ("w1", &w1, c::W_MAX), ("w2", &w2, c::W_MAX)]
        {
            for &v in w.iter() {
                anyhow::ensure!(
                    v == v.trunc() && v.abs() <= limit as f32,
                    "{name} value {v} off the 6-bit grid"
                );
            }
        }

        let gain_flat = j.req("gain")?.to_f32_vec()?;
        let offset_flat = j.req("offset")?.to_f32_vec()?;
        anyhow::ensure!(gain_flat.len() == 2 * c::N_COLS, "gain shape");
        anyhow::ensure!(offset_flat.len() == 2 * c::N_COLS, "offset shape");

        let scales_v = j.req("scales")?.to_f32_vec()?;
        anyhow::ensure!(scales_v.len() == 3, "expected 3 scales");

        let mut train_metrics = std::collections::BTreeMap::new();
        if let Some(m) = j.get("metrics").and_then(|m| m.as_obj()) {
            for (k, v) in m {
                if let Some(x) = v.as_f64() {
                    train_metrics.insert(k.clone(), x);
                }
            }
        }

        Ok(TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            scales: [scales_v[0], scales_v[1], scales_v[2]],
            gain: [
                gain_flat[..c::N_COLS].to_vec(),
                gain_flat[c::N_COLS..].to_vec(),
            ],
            offset: [
                offset_flat[..c::N_COLS].to_vec(),
                offset_flat[c::N_COLS..].to_vec(),
            ],
            noise_sigma: j
                .get("noise_sigma")
                .and_then(|v| v.as_f64())
                .unwrap_or(c::NOISE_SIGMA),
            train_metrics,
        })
    }

    /// Deterministic synthetic model for tests, benches, and fleet
    /// bring-up without trained artifacts: on-grid (6-bit) weights from a
    /// seeded stream, nominal calibration, the paper's noise sigma.  Not a
    /// trained classifier — predictions are arbitrary but reproducible.
    pub fn synthetic(seed: u64) -> TrainedModel {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        let mut grid = |n: usize| -> Vec<f32> {
            (0..n)
                .map(|_| {
                    (rng.below(2 * c::W_MAX as u64 + 1) as i64 - c::W_MAX as i64)
                        as f32
                })
                .collect()
        };
        let wc = grid(c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL);
        let w1 = grid(c::K_LOGICAL * c::FC1_OUT);
        let w2 = grid(c::FC1_OUT * c::FC2_OUT);
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            scales: [0.02, 0.02, 0.02],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: c::NOISE_SIGMA,
            train_metrics: Default::default(),
        }
    }

    /// Untrained **energy-detector** model for the monitoring demo and
    /// streaming bench: every weight +1, nominal calibration, layer
    /// scales picked so the all-positive chain stays inside the ADC and
    /// 5-bit requantisation ranges without saturating.  Class scores
    /// then grow monotonically with total input activation — afib's
    /// elevated derivative energy (the feature fully-analog ECG
    /// front-ends exploit, cf. EKGNet) is detectable by thresholding the
    /// score sum against a sinus lead-in, no trained artifacts needed.
    /// Not a classifier: `pred` is meaningless for this model.
    pub fn energy_detector() -> TrainedModel {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![1.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![1.0; c::FC1_OUT * c::FC2_OUT];
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            // All-ones sums per column: conv ~100–160 activation units
            // (16 taps × mean act 6–10), fc1 ~2–3k (256 inputs), fc2
            // ~0.7–1.1k (123 inputs).  These scales land each stage at a
            // few tens of ADC LSB — meaningful signal above the 2 LSB
            // analog noise, yet clear of the ±127 LSB rail and the
            // post-shift 5-bit cap, so the energy response stays
            // monotone instead of saturating.
            scales: [0.25, 0.015, 0.05],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: c::NOISE_SIGMA,
            train_metrics: Default::default(),
        }
    }

    /// The array half a pass executes on (conv: top, fc1/fc2: bottom).
    pub fn pass_half(pass: usize) -> usize {
        if pass == 0 {
            0
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_weights_json() -> String {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![-2.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![3.0; c::FC1_OUT * c::FC2_OUT];
        let gain = vec![vec![1.0; c::N_COLS]; 2];
        let offset = vec![vec![0.0; c::N_COLS]; 2];
        format!(
            r#"{{"format":"bss2-weights-v1","scales":[0.1,0.2,0.3],
               "wc":{:?},"w1":{:?},"w2":{:?},"gain":{:?},"offset":{:?},
               "noise_sigma":2.0,"metrics":{{"test_acc_mean":0.9}}}}"#,
            wc, w1, w2, gain, offset
        )
    }

    #[test]
    fn parse_roundtrip() {
        let m = TrainedModel::parse(&tiny_weights_json()).unwrap();
        assert_eq!(m.scales, [0.1, 0.2, 0.3]);
        assert_eq!(m.gain[0].len(), c::N_COLS);
        assert_eq!(m.pass_weights[0].len(), c::K_LOGICAL * c::N_COLS);
        assert_eq!(m.train_metrics["test_acc_mean"], 0.9);
        // fc1 block A carries -2.
        assert_eq!(m.pass_weights[1][0], -2.0);
        // fc2 block carries 3 at (0, 246).
        assert_eq!(m.pass_weights[2][2 * c::FC1_OUT], 3.0);
    }

    #[test]
    fn rejects_bad_format() {
        let bad = tiny_weights_json().replace("bss2-weights-v1", "v0");
        assert!(TrainedModel::parse(&bad).is_err());
    }

    #[test]
    fn rejects_off_grid_weights() {
        let bad = tiny_weights_json().replacen("-2.0", "-2.5", 1);
        let err = TrainedModel::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("6-bit grid"), "{err}");
        let bad2 = tiny_weights_json().replacen("3.0", "64.0", 1);
        assert!(TrainedModel::parse(&bad2).is_err());
    }

    #[test]
    fn synthetic_is_deterministic_and_on_grid() {
        let a = TrainedModel::synthetic(9);
        let b = TrainedModel::synthetic(9);
        assert_eq!(a.pass_weights[0], b.pass_weights[0]);
        assert_eq!(a.pass_weights[2], b.pass_weights[2]);
        for m in a.pass_weights.iter() {
            assert_eq!(m.len(), c::K_LOGICAL * c::N_COLS);
            for &w in m.iter() {
                assert!(w == w.trunc() && w.abs() <= c::W_MAX as f32);
            }
        }
        let c2 = TrainedModel::synthetic(10);
        assert_ne!(a.pass_weights[0], c2.pass_weights[0], "seed matters");
    }

    #[test]
    fn pass_halves() {
        assert_eq!(TrainedModel::pass_half(0), 0);
        assert_eq!(TrainedModel::pass_half(1), 1);
        assert_eq!(TrainedModel::pass_half(2), 1);
    }
}
