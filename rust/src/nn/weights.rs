//! Trained-model loading: `weights.json` → packed physical matrices +
//! calibration + per-layer scales.

use std::path::Path;

use crate::asic::consts as c;
use crate::util::json::Json;

use super::mapping;

/// The trained ECG model in physical form, ready for the engine.
#[derive(Debug, Clone)]
pub struct TrainedModel {
    /// Physical matrices for the three passes (conv, fc1, fc2).
    pub pass_weights: [mapping::PhysMatrix; 3],
    /// Per-layer amplification (paper's right-shift configuration).
    pub scales: [f32; 3],
    /// Per-half calibration `[half][col]`.
    pub gain: [Vec<f32>; 2],
    pub offset: [Vec<f32>; 2],
    pub noise_sigma: f64,
    /// Training-time metrics recorded in the artifact.
    pub train_metrics: std::collections::BTreeMap<String, f64>,
}

impl TrainedModel {
    pub fn load(path: &Path) -> anyhow::Result<TrainedModel> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<TrainedModel> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("weights.json: {e}"))?;
        let format = j.req("format")?.as_str().unwrap_or("");
        anyhow::ensure!(
            format == "bss2-weights-v1",
            "unsupported weights format `{format}`"
        );

        let wc = j.req("wc")?.to_f32_vec()?;
        let w1 = j.req("w1")?.to_f32_vec()?;
        let w2 = j.req("w2")?.to_f32_vec()?;
        for (name, w, limit) in
            [("wc", &wc, c::W_MAX), ("w1", &w1, c::W_MAX), ("w2", &w2, c::W_MAX)]
        {
            for &v in w.iter() {
                anyhow::ensure!(
                    v == v.trunc() && v.abs() <= limit as f32,
                    "{name} value {v} off the 6-bit grid"
                );
            }
        }

        let gain_flat = j.req("gain")?.to_f32_vec()?;
        let offset_flat = j.req("offset")?.to_f32_vec()?;
        anyhow::ensure!(gain_flat.len() == 2 * c::N_COLS, "gain shape");
        anyhow::ensure!(offset_flat.len() == 2 * c::N_COLS, "offset shape");

        let scales_v = j.req("scales")?.to_f32_vec()?;
        anyhow::ensure!(scales_v.len() == 3, "expected 3 scales");

        let mut train_metrics = std::collections::BTreeMap::new();
        if let Some(m) = j.get("metrics").and_then(|m| m.as_obj()) {
            for (k, v) in m {
                if let Some(x) = v.as_f64() {
                    train_metrics.insert(k.clone(), x);
                }
            }
        }

        Ok(TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            scales: [scales_v[0], scales_v[1], scales_v[2]],
            gain: [
                gain_flat[..c::N_COLS].to_vec(),
                gain_flat[c::N_COLS..].to_vec(),
            ],
            offset: [
                offset_flat[..c::N_COLS].to_vec(),
                offset_flat[c::N_COLS..].to_vec(),
            ],
            noise_sigma: j
                .get("noise_sigma")
                .and_then(|v| v.as_f64())
                .unwrap_or(c::NOISE_SIGMA),
            train_metrics,
        })
    }

    /// Serialise to the same `bss2-weights-v1` JSON that [`parse`]
    /// consumes (the writer `load`/`parse` never had — the training loop
    /// emits its artifact through this).  Physical matrices are unpacked
    /// back to *logical* weights (`mapping::unpack_*`), so the file stays
    /// interchangeable with the python exporter's layout; packing on load
    /// reproduces the matrices bit-identically (`unpack ∘ pack = id`).
    /// f32 values survive the JSON round trip exactly (shortest-roundtrip
    /// printing, same guarantee the calibration profiles rely on).
    ///
    /// [`parse`]: TrainedModel::parse
    pub fn to_json(&self) -> String {
        let vec_f32 = |v: &[f32]| {
            Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("format".into(), Json::Str("bss2-weights-v1".into()));
        m.insert(
            "wc".into(),
            vec_f32(&mapping::unpack_conv(&self.pass_weights[0])),
        );
        m.insert(
            "w1".into(),
            vec_f32(&mapping::unpack_fc1(&self.pass_weights[1])),
        );
        m.insert(
            "w2".into(),
            vec_f32(&mapping::unpack_fc2(&self.pass_weights[2])),
        );
        m.insert(
            "scales".into(),
            vec_f32(&[self.scales[0], self.scales[1], self.scales[2]]),
        );
        let flat = |halves: &[Vec<f32>; 2]| {
            let mut v = halves[0].clone();
            v.extend_from_slice(&halves[1]);
            v
        };
        m.insert("gain".into(), vec_f32(&flat(&self.gain)));
        m.insert("offset".into(), vec_f32(&flat(&self.offset)));
        m.insert("noise_sigma".into(), Json::Num(self.noise_sigma));
        if !self.train_metrics.is_empty() {
            let metrics = self
                .train_metrics
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect();
            m.insert("metrics".into(), Json::Obj(metrics));
        }
        Json::Obj(m).to_string()
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Deterministic synthetic model for tests, benches, and fleet
    /// bring-up without trained artifacts: on-grid (6-bit) weights from a
    /// seeded stream, nominal calibration, the paper's noise sigma.  Not a
    /// trained classifier — predictions are arbitrary but reproducible.
    pub fn synthetic(seed: u64) -> TrainedModel {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        let mut grid = |n: usize| -> Vec<f32> {
            (0..n)
                .map(|_| {
                    (rng.below(2 * c::W_MAX as u64 + 1) as i64 - c::W_MAX as i64)
                        as f32
                })
                .collect()
        };
        let wc = grid(c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL);
        let w1 = grid(c::K_LOGICAL * c::FC1_OUT);
        let w2 = grid(c::FC1_OUT * c::FC2_OUT);
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            scales: [0.02, 0.02, 0.02],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: c::NOISE_SIGMA,
            train_metrics: Default::default(),
        }
    }

    /// Untrained **energy-detector** model for the monitoring demo and
    /// streaming bench: every weight +1, nominal calibration, layer
    /// scales picked so the all-positive chain stays inside the ADC and
    /// 5-bit requantisation ranges without saturating.  Class scores
    /// then grow monotonically with total input activation — afib's
    /// elevated derivative energy (the feature fully-analog ECG
    /// front-ends exploit, cf. EKGNet) is detectable by thresholding the
    /// score sum against a sinus lead-in, no trained artifacts needed.
    /// Not a classifier: `pred` is meaningless for this model.
    pub fn energy_detector() -> TrainedModel {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![1.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![1.0; c::FC1_OUT * c::FC2_OUT];
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            // All-ones sums per column: conv ~100–160 activation units
            // (16 taps × mean act 6–10), fc1 ~2–3k (256 inputs), fc2
            // ~0.7–1.1k (123 inputs).  These scales land each stage at a
            // few tens of ADC LSB — meaningful signal above the 2 LSB
            // analog noise, yet clear of the ±127 LSB rail and the
            // post-shift 5-bit cap, so the energy response stays
            // monotone instead of saturating.
            scales: [0.25, 0.015, 0.05],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: c::NOISE_SIGMA,
            train_metrics: Default::default(),
        }
    }

    /// The array half a pass executes on (conv: top, fc1/fc2: bottom).
    pub fn pass_half(pass: usize) -> usize {
        if pass == 0 {
            0
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_weights_json() -> String {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![-2.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![3.0; c::FC1_OUT * c::FC2_OUT];
        let gain = vec![vec![1.0; c::N_COLS]; 2];
        let offset = vec![vec![0.0; c::N_COLS]; 2];
        format!(
            r#"{{"format":"bss2-weights-v1","scales":[0.1,0.2,0.3],
               "wc":{:?},"w1":{:?},"w2":{:?},"gain":{:?},"offset":{:?},
               "noise_sigma":2.0,"metrics":{{"test_acc_mean":0.9}}}}"#,
            wc, w1, w2, gain, offset
        )
    }

    #[test]
    fn parse_roundtrip() {
        let m = TrainedModel::parse(&tiny_weights_json()).unwrap();
        assert_eq!(m.scales, [0.1, 0.2, 0.3]);
        assert_eq!(m.gain[0].len(), c::N_COLS);
        assert_eq!(m.pass_weights[0].len(), c::K_LOGICAL * c::N_COLS);
        assert_eq!(m.train_metrics["test_acc_mean"], 0.9);
        // fc1 block A carries -2.
        assert_eq!(m.pass_weights[1][0], -2.0);
        // fc2 block carries 3 at (0, 246).
        assert_eq!(m.pass_weights[2][2 * c::FC1_OUT], 3.0);
    }

    #[test]
    fn rejects_bad_format() {
        let bad = tiny_weights_json().replace("bss2-weights-v1", "v0");
        assert!(TrainedModel::parse(&bad).is_err());
    }

    #[test]
    fn rejects_off_grid_weights() {
        let bad = tiny_weights_json().replacen("-2.0", "-2.5", 1);
        let err = TrainedModel::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("6-bit grid"), "{err}");
        let bad2 = tiny_weights_json().replacen("3.0", "64.0", 1);
        assert!(TrainedModel::parse(&bad2).is_err());
    }

    #[test]
    fn synthetic_is_deterministic_and_on_grid() {
        let a = TrainedModel::synthetic(9);
        let b = TrainedModel::synthetic(9);
        assert_eq!(a.pass_weights[0], b.pass_weights[0]);
        assert_eq!(a.pass_weights[2], b.pass_weights[2]);
        for m in a.pass_weights.iter() {
            assert_eq!(m.len(), c::K_LOGICAL * c::N_COLS);
            for &w in m.iter() {
                assert!(w == w.trunc() && w.abs() <= c::W_MAX as f32);
            }
        }
        let c2 = TrainedModel::synthetic(10);
        assert_ne!(a.pass_weights[0], c2.pass_weights[0], "seed matters");
    }

    #[test]
    fn to_json_parse_roundtrip_is_exact() {
        let mut m = TrainedModel::synthetic(21);
        m.train_metrics.insert("val_det".into(), 0.875);
        let q = TrainedModel::parse(&m.to_json()).unwrap();
        for p in 0..3 {
            assert_eq!(
                q.pass_weights[p], m.pass_weights[p],
                "pass {p} weights must roundtrip bit-identically"
            );
        }
        assert_eq!(q.scales, m.scales, "thresholds/scales must roundtrip");
        assert_eq!(q.gain, m.gain);
        assert_eq!(q.offset, m.offset);
        assert_eq!(q.noise_sigma, m.noise_sigma);
        assert_eq!(q.train_metrics, m.train_metrics);
    }

    #[test]
    fn save_load_roundtrip() {
        let m = TrainedModel::energy_detector();
        let path = std::env::temp_dir().join("bss2_weights_writer_test.json");
        m.save(&path).unwrap();
        let q = TrainedModel::load(&path).unwrap();
        assert_eq!(q.pass_weights[0], m.pass_weights[0]);
        assert_eq!(q.scales, m.scales);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pass_halves() {
        assert_eq!(TrainedModel::pass_half(0), 0);
        assert_eq!(TrainedModel::pass_half(1), 1);
        assert_eq!(TrainedModel::pass_half(2), 1);
    }
}
