//! Data-flow graph of network layers (paper §II-D "Data-Flow Graph
//! Execution" — the hxtorch-equivalent model description the JIT walks).
//!
//! A [`Graph`] is a linear chain of [`Op`]s over integer activation
//! vectors.  `lower()` walks the graph and emits the SIMD-CPU instruction
//! stream + pass schedule the standalone engine executes — the "converted
//! into configuration data and control flow statements" step of the paper.

use crate::asic::consts as c;
use crate::asic::simd::Insn;

use super::partition::{partition, Plan};

/// Graph operations (what the user-level model description contains).
#[derive(Debug, Clone)]
pub enum Op {
    /// Analog VMM against physical pass `pass_idx` on `half` (pre-packed
    /// matrices; conv is expressed as its Toeplitz matrix).
    AnalogPass { pass_idx: usize, half: u8 },
    /// Digital partial-sum add of two column windows (fc1's split blocks).
    PartialSum { a_off: u16, b_off: u16, len: u16 },
    /// Digital ReLU + right-shift requantisation.
    ReluShift { shift: u8 },
    /// Slice a window out of the activation vector.
    Window { off: u16, len: u16 },
    /// Average-pool groups (the 10 → 2 output reduction).
    AvgPool { group: u16, groups: u16 },
    /// Final argmax over the first `len` lanes.
    ArgMax { len: u16 },
}

/// The ECG network of paper Fig 6 as a data-flow graph.
pub fn ecg_network() -> Graph {
    Graph {
        ops: vec![
            Op::AnalogPass { pass_idx: 0, half: 0 }, // conv on upper half
            Op::ReluShift { shift: c::RELU_SHIFT as u8 },
            Op::AnalogPass { pass_idx: 1, half: 1 }, // fc1 on lower half
            Op::PartialSum { a_off: 0, b_off: c::FC1_OUT as u16, len: c::FC1_OUT as u16 },
            Op::ReluShift { shift: c::RELU_SHIFT as u8 },
            Op::AnalogPass { pass_idx: 2, half: 1 }, // fc2 on lower half
            Op::Window { off: 2 * c::FC1_OUT as u16, len: c::FC2_OUT as u16 },
            Op::AvgPool { group: c::POOL_GROUP as u16, groups: c::N_CLASSES as u16 },
            Op::ArgMax { len: c::N_CLASSES as u16 },
        ],
    }
}

#[derive(Debug, Clone)]
pub struct Graph {
    pub ops: Vec<Op>,
}

impl Graph {
    /// Number of analog passes (integration cycles) per inference.
    pub fn analog_passes(&self) -> usize {
        self.ops
            .iter()
            .filter(|o| matches!(o, Op::AnalogPass { .. }))
            .count()
    }

    /// Lower the graph to a SIMD instruction stream.  Register allocation
    /// is a simple two-register rotation (act in v0, scratch v1/v2); the
    /// result is stored to slot 1.
    pub fn lower(&self) -> Vec<Insn> {
        let mut s = Vec::new();
        s.push(Insn::LoadActivations { dst: 0, src_slot: 0 });
        s.push(Insn::WaitDma);
        for op in &self.ops {
            match *op {
                Op::AnalogPass { pass_idx: _, half } => {
                    s.push(Insn::TriggerEvents { half, src: 0 });
                    s.push(Insn::TriggerVmm { half });
                    s.push(Insn::ReadAdc { half, dst: 1 });
                    s.push(Insn::Mov { dst: 0, src: 1 });
                }
                Op::PartialSum { a_off, b_off, len } => {
                    s.push(Insn::Slice { dst: 1, src: 0, offset: a_off, len });
                    s.push(Insn::Slice { dst: 2, src: 0, offset: b_off, len });
                    s.push(Insn::Add { dst: 0, a: 1, b: 2 });
                }
                Op::ReluShift { shift } => {
                    s.push(Insn::Relu { dst: 0, src: 0 });
                    s.push(Insn::ShiftRight { dst: 0, src: 0, shift });
                    s.push(Insn::Clamp { dst: 0, src: 0, lo: 0, hi: c::X_MAX });
                }
                Op::Window { off, len } => {
                    s.push(Insn::Slice { dst: 0, src: 0, offset: off, len });
                }
                Op::AvgPool { group, groups } => {
                    s.push(Insn::AvgPool { dst: 0, src: 0, group, groups });
                }
                Op::ArgMax { len } => {
                    s.push(Insn::ArgMax { src: 0, len });
                }
            }
        }
        s.push(Insn::StoreResult { src: 0, dst_slot: 1 });
        s
    }

    /// Resource summary for arbitrary models: how many chip passes a
    /// sequence of dense layer shapes costs after partitioning (paper §V:
    /// model size bounded only by memory).
    pub fn plan_layers(layers: &[(usize, usize)], halves: usize) -> Vec<Plan> {
        layers
            .iter()
            .map(|&(i, o)| partition(i, o, halves))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ecg_graph_has_three_passes() {
        let g = ecg_network();
        assert_eq!(g.analog_passes(), 3);
    }

    #[test]
    fn lowered_stream_structure() {
        let g = ecg_network();
        let s = g.lower();
        // 3 passes x 4 insns + load/wait + 2x relu-shift(3) + psum(3)
        // + window + pool + argmax + store
        let triggers = s
            .iter()
            .filter(|i| matches!(i, Insn::TriggerVmm { .. }))
            .count();
        assert_eq!(triggers, 3);
        assert!(matches!(s[0], Insn::LoadActivations { .. }));
        assert!(matches!(s.last().unwrap(), Insn::StoreResult { .. }));
        let argmaxes = s.iter().filter(|i| matches!(i, Insn::ArgMax { .. })).count();
        assert_eq!(argmaxes, 1);
    }

    #[test]
    fn pass_halves_follow_fig6() {
        let g = ecg_network();
        let halves: Vec<u8> = g
            .ops
            .iter()
            .filter_map(|o| match o {
                Op::AnalogPass { half, .. } => Some(*half),
                _ => None,
            })
            .collect();
        assert_eq!(halves, vec![0, 1, 1]);
    }

    #[test]
    fn plan_layers_multi_chip() {
        let plans = Graph::plan_layers(&[(1000, 500), (500, 10)], 2);
        assert_eq!(plans.len(), 2);
        assert!(plans[0].passes() > 1);
        for p in &plans {
            p.check_invariants().unwrap();
        }
    }
}
