//! Multi-chunk plan executor — the hxtorch "Hardware Resources" contract
//! (paper §II-D): arbitrary-size linear layers run on the fixed-size analog
//! substrate by executing their partitioned [`Plan`] chunk by chunk,
//! accumulating partial sums digitally (SIMD CPUs) and requantising between
//! layers.  Paper §V: "rate-based stateless operation ... allows for
//! multiplexing hardware resources in time and therefore has the advantage
//! of supporting arbitrarily large model sizes".
//!
//! The executor drives any [`PassRunner`] — the native analog array model
//! here, the PJRT artifact in the engine — and is validated against a float
//! reference on random layer stacks (quantisation-aware, see tests).

use crate::asic::array::{AnalogArray, ColumnCalib};
use crate::asic::consts as c;

use super::partition::{partition, Plan};

/// Anything that can run one physical integration cycle of a chip-sized
/// weight tile: `x` (5-bit activations, len == chunk in_len) against a
/// `in_len x out_len` tile, returning signed ADC counts.
pub trait PassRunner {
    fn run_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>>;

    /// Integration cycles executed so far (for cost accounting).
    fn passes(&self) -> usize;
}

/// Native-model runner: loads each tile into an analog array half and
/// integrates (noise-free by default; the engine path carries noise).
pub struct NativeRunner {
    array: AnalogArray,
    passes: usize,
    pub noise: Vec<f32>,
}

impl Default for NativeRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeRunner {
    pub fn new() -> NativeRunner {
        NativeRunner {
            array: AnalogArray::new(
                c::K_LOGICAL,
                c::N_COLS,
                ColumnCalib::nominal(c::N_COLS),
            ),
            passes: 0,
            noise: vec![0.0; c::N_COLS],
        }
    }
}

impl PassRunner for NativeRunner {
    fn run_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>> {
        anyhow::ensure!(in_len <= c::K_LOGICAL && out_len <= c::N_COLS);
        anyhow::ensure!(w_tile.len() == in_len * out_len);
        anyhow::ensure!(x.len() == in_len);
        // Pack the tile into the physical array (zero-padded).
        let mut w_phys = vec![0i8; c::K_LOGICAL * c::N_COLS];
        for r in 0..in_len {
            for col in 0..out_len {
                w_phys[r * c::N_COLS + col] =
                    (w_tile[r * out_len + col] as i32)
                        .clamp(-c::W_MAX, c::W_MAX) as i8;
            }
        }
        self.array.load_weights(&w_phys);
        let mut x_phys = vec![0u8; c::K_LOGICAL];
        x_phys[..in_len].copy_from_slice(x);
        let out = self.array.integrate(&x_phys, scale, &self.noise, false);
        self.passes += 1;
        Ok(out[..out_len].to_vec())
    }

    fn passes(&self) -> usize {
        self.passes
    }
}

/// One linear layer of an arbitrary-size model.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Row-major `[in_dim][out_dim]` integer weights on the 6-bit grid.
    pub weights: Vec<f32>,
    pub scale: f32,
    /// Apply ReLU + >>RELU_SHIFT requantisation after this layer.
    pub relu_requant: bool,
}

/// Execute one layer's plan: chunks -> tiles -> digital partial sums.
/// Partial sums accumulate in i32 (the SIMD CPUs' width) **before** any
/// nonlinearity, exactly like fc1's split blocks in the paper's Fig 6.
pub fn run_layer<R: PassRunner>(
    runner: &mut R,
    layer: &LayerSpec,
    plan: &Plan,
    x: &[u8],
) -> anyhow::Result<Vec<i32>> {
    anyhow::ensure!(x.len() == layer.in_dim, "input dim");
    anyhow::ensure!(
        plan.in_dim == layer.in_dim && plan.out_dim == layer.out_dim,
        "plan/layer mismatch"
    );
    let mut out = vec![0i32; layer.out_dim];
    for chunk in &plan.chunks {
        // Slice the weight tile of this chunk.
        let (il, ol) = (chunk.in_len(), chunk.out_len());
        let mut tile = vec![0.0f32; il * ol];
        for (ri, r) in (chunk.in_start..chunk.in_end).enumerate() {
            for (ci, col) in (chunk.out_start..chunk.out_end).enumerate() {
                tile[ri * ol + ci] = layer.weights[r * layer.out_dim + col];
            }
        }
        let adc = runner.run_tile(
            &tile,
            il,
            ol,
            &x[chunk.in_start..chunk.in_end],
            layer.scale,
        )?;
        for (ci, &v) in adc.iter().enumerate() {
            out[chunk.out_start + ci] += v as i32; // digital partial sum
        }
    }
    Ok(out)
}

/// Execute a stack of layers end to end (5-bit activations between layers).
pub fn run_model<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    input: &[u8],
) -> anyhow::Result<Vec<i32>> {
    anyhow::ensure!(!layers.is_empty());
    let mut acts: Vec<u8> = input.to_vec();
    let mut last_raw: Vec<i32> = acts.iter().map(|&a| a as i32).collect();
    for layer in layers {
        let plan = partition(layer.in_dim, layer.out_dim, c::N_HALVES);
        plan.check_invariants().map_err(|e| anyhow::anyhow!(e))?;
        let raw = run_layer(runner, layer, &plan, &acts)?;
        if layer.relu_requant {
            acts = raw
                .iter()
                .map(|&v| {
                    ((v.max(0) >> c::RELU_SHIFT).min(c::X_MAX)) as u8
                })
                .collect();
        } else {
            acts = raw
                .iter()
                .map(|&v| v.clamp(0, c::X_MAX) as u8)
                .collect();
        }
        last_raw = raw;
    }
    Ok(last_raw)
}

/// Cost model: integration cycles + simulated chip time for a layer stack
/// (paper §III-A: oversize networks pay reconfiguration/serialisation).
pub fn cost_of(layers: &[(usize, usize)]) -> (usize, f64) {
    let passes: usize = layers
        .iter()
        .map(|&(i, o)| partition(i, o, c::N_HALVES).passes())
        .sum();
    let time_us = passes as f64 * c::INTEGRATION_CYCLE_US;
    (passes, time_us)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck;
    use crate::util::rng::SplitMix64;

    fn rand_layer(
        rng: &mut SplitMix64,
        in_dim: usize,
        out_dim: usize,
        relu: bool,
    ) -> LayerSpec {
        LayerSpec {
            in_dim,
            out_dim,
            weights: (0..in_dim * out_dim)
                .map(|_| (rng.below(2 * c::W_MAX as u64 + 1) as i32
                    - c::W_MAX) as f32)
                .collect(),
            scale: 0.002,
            relu_requant: relu,
        }
    }

    /// Float reference for a single layer in the linear regime.
    fn dense_ref(layer: &LayerSpec, x: &[u8]) -> Vec<f64> {
        let mut out = vec![0.0f64; layer.out_dim];
        for (r, &xv) in x.iter().enumerate() {
            for col in 0..layer.out_dim {
                out[col] += xv as f64
                    * layer.weights[r * layer.out_dim + col] as f64;
            }
        }
        out
    }

    #[test]
    fn single_chip_layer_matches_reference() {
        let mut rng = SplitMix64::new(1);
        let layer = rand_layer(&mut rng, 200, 100, false);
        let x: Vec<u8> = (0..200).map(|_| rng.below(4) as u8).collect();
        let plan = partition(200, 100, 2);
        let mut runner = NativeRunner::new();
        let got = run_layer(&mut runner, &layer, &plan, &x).unwrap();
        let want = dense_ref(&layer, &x);
        for (g, w) in got.iter().zip(&want) {
            let expect = (w * layer.scale as f64).round().clamp(-128.0, 127.0);
            assert!(
                (*g as f64 - expect).abs() <= 1.0,
                "got {g} want {expect}"
            );
        }
        assert_eq!(runner.passes(), 1);
    }

    #[test]
    fn oversize_layer_partial_sums() {
        // 600 inputs -> 3 input tiles; digital accumulation must match the
        // direct dense product in the linear regime.
        let mut rng = SplitMix64::new(2);
        let layer = rand_layer(&mut rng, 600, 300, false);
        // Small activations keep each *partial* sum inside the ADC range.
        let x: Vec<u8> = (0..600).map(|_| rng.below(2) as u8).collect();
        let plan = partition(600, 300, 2);
        let mut runner = NativeRunner::new();
        let got = run_layer(&mut runner, &layer, &plan, &x).unwrap();
        assert_eq!(runner.passes(), plan.passes());
        let want = dense_ref(&layer, &x);
        let mut worst = 0.0f64;
        for (g, w) in got.iter().zip(&want) {
            let expect = w * layer.scale as f64;
            worst = worst.max((*g as f64 - expect).abs());
        }
        // Each tile rounds independently: error <= 0.5 LSB per input tile.
        assert!(worst <= 3.0 * 0.5 + 1e-9, "worst {worst}");
    }

    #[test]
    fn multi_layer_stack_runs() {
        let mut rng = SplitMix64::new(3);
        let layers = vec![
            rand_layer(&mut rng, 300, 400, true),
            rand_layer(&mut rng, 400, 150, true),
            rand_layer(&mut rng, 150, 10, false),
        ];
        let x: Vec<u8> = (0..300).map(|_| rng.below(8) as u8).collect();
        let mut runner = NativeRunner::new();
        let out = run_model(&mut runner, &layers, &x).unwrap();
        assert_eq!(out.len(), 10);
        // 300x400: 2x2=4 chunks; 400x150: 2 chunks; 150x10: 1 chunk.
        assert_eq!(runner.passes(), 4 + 2 + 1);
    }

    #[test]
    fn executor_equivalence_property() {
        propcheck::check("executor_matches_dense", 12, 0xFACE, |g| {
            let in_dim = g.usize_in(1, 520);
            let out_dim = g.usize_in(1, 300);
            let mut rng = SplitMix64::new(g.rng.next_u64());
            let layer = rand_layer(&mut rng, in_dim, out_dim, false);
            let x: Vec<u8> =
                (0..in_dim).map(|_| rng.below(2) as u8).collect();
            let plan = partition(in_dim, out_dim, 2);
            let mut runner = NativeRunner::new();
            let got = run_layer(&mut runner, &layer, &plan, &x)
                .map_err(|e| e.to_string())?;
            let want = dense_ref(&layer, &x);
            let tiles = in_dim.div_ceil(c::K_LOGICAL) as f64;
            for (i, (gv, wv)) in got.iter().zip(&want).enumerate() {
                let expect = wv * layer.scale as f64;
                // Only check columns whose exact value stays linear.
                if expect.abs() < 100.0 {
                    prop_assert!(
                        (*gv as f64 - expect).abs() <= 0.5 * tiles + 1e-6,
                        "col {i}: got {gv} want {expect}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cost_model_scales() {
        let (p_small, t_small) = cost_of(&[(256, 256)]);
        assert_eq!(p_small, 1);
        assert!((t_small - c::INTEGRATION_CYCLE_US).abs() < 1e-9);
        let (p_big, _) = cost_of(&[(1024, 1024)]);
        assert_eq!(p_big, 16);
        // Paper §V scale: a 10M-parameter model is time-multiplexable.
        let (p_huge, t_huge) = cost_of(&[(3000, 3000), (3000, 1000)]);
        assert!(p_huge > 100);
        assert!(t_huge > 500.0);
    }
}
