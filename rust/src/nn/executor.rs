//! Multi-chunk plan executor — the hxtorch "Hardware Resources" contract
//! (paper §II-D): arbitrary-size linear layers run on the fixed-size analog
//! substrate by executing their partitioned [`Plan`] chunk by chunk,
//! accumulating partial sums digitally (SIMD CPUs) and requantising between
//! layers.  Paper §V: "rate-based stateless operation ... allows for
//! multiplexing hardware resources in time and therefore has the advantage
//! of supporting arbitrarily large model sizes".
//!
//! The executor drives any [`PassRunner`] — the native analog array model
//! here, the PJRT artifact in the engine — and is validated against a float
//! reference on random layer stacks (quantisation-aware, see tests).

use crate::asic::array::{AnalogArray, ColumnCalib};
use crate::asic::consts as c;

use super::partition::{partition, Plan};

/// Anything that can run one physical integration cycle of a chip-sized
/// weight tile: `x` (5-bit activations, len == chunk in_len) against a
/// `in_len x out_len` tile, returning signed ADC counts.
pub trait PassRunner {
    fn run_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>>;

    /// Batched variant of [`run_tile`](PassRunner::run_tile): integrate
    /// every activation vector in `xs` against the *same* weight tile.
    /// Backends override this to write the tile once and loop only the
    /// integration (the hxtorch batching lever); the default degrades to
    /// one reconfiguration per sample, so results are bit-identical
    /// either way.
    fn run_tile_batch(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        xs: &[Vec<u8>],
        scale: f32,
    ) -> anyhow::Result<Vec<Vec<i16>>> {
        xs.iter()
            .map(|x| self.run_tile(w_tile, in_len, out_len, x, scale))
            .collect()
    }

    /// Flat batch-major variant of
    /// [`run_tile_batch`](PassRunner::run_tile_batch): `xs` is `batch ×
    /// in_len` row-major, `out` is `batch × out_len` row-major and fully
    /// overwritten (DESIGN.md §17).  The default round-trips through
    /// `run_tile_batch`, so any backend's flat results are bit-identical
    /// to its nested ones by construction; `NativeRunner` overrides with
    /// an allocation-free scratch path.
    fn run_tile_batch_into(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        xs: &[u8],
        batch: usize,
        scale: f32,
        out: &mut [i16],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(xs.len() == batch * in_len, "batch input shape");
        anyhow::ensure!(out.len() == batch * out_len, "batch output shape");
        let xs_vec: Vec<Vec<u8>> =
            xs.chunks_exact(in_len).map(|x| x.to_vec()).collect();
        let adcs =
            self.run_tile_batch(w_tile, in_len, out_len, &xs_vec, scale)?;
        anyhow::ensure!(adcs.len() == batch, "runner batch shape");
        for (o, adc) in out.chunks_exact_mut(out_len).zip(&adcs) {
            anyhow::ensure!(adc.len() == out_len, "tile output shape");
            o.copy_from_slice(adc);
        }
        Ok(())
    }

    /// Integration cycles executed so far (for cost accounting).
    fn passes(&self) -> usize;

    /// Weight reconfigurations (tile writes) so far.  Backends that do
    /// not track reconfiguration pay one write per pass.
    fn weight_loads(&self) -> usize {
        self.passes()
    }
}

/// Reusable per-runner buffers for the integrate hot path (DESIGN.md §17):
/// the physical activation vector, the i32 charge accumulator, the i16 ADC
/// row, and the packed physical weight tile.  Every pass writes into these
/// instead of allocating.  `x_dirty` and `w_rows`/`w_cols` record how much
/// of each buffer the previous pass may have left non-zero, so only the
/// stale region is re-zeroed — the zero-padding invariant the array model
/// relies on is maintained without a full-width fill per pass.
struct PassScratch {
    x_phys: Vec<u8>,
    /// Rows `[0, x_dirty)` of `x_phys` may hold the previous pass's
    /// activations; everything beyond is guaranteed zero.
    x_dirty: usize,
    acc: Vec<i32>,
    adc: Vec<i16>,
    w_phys: Vec<i8>,
    /// Rectangle `[0, w_rows) × [0, w_cols)` of `w_phys` may hold the
    /// previous tile's weights; everything outside is guaranteed zero.
    w_rows: usize,
    w_cols: usize,
}

impl PassScratch {
    fn new() -> PassScratch {
        PassScratch {
            x_phys: vec![0; c::K_LOGICAL],
            x_dirty: 0,
            acc: vec![0; c::N_COLS],
            adc: vec![0; c::N_COLS],
            w_phys: vec![0; c::K_LOGICAL * c::N_COLS],
            w_rows: 0,
            w_cols: 0,
        }
    }
}

/// Native-model runner: loads each tile into an analog array half and
/// integrates (noise-free by default; the engine path carries noise).
pub struct NativeRunner {
    array: AnalogArray,
    passes: usize,
    weight_loads: usize,
    pub noise: Vec<f32>,
    /// Optional post-ADC calibration correction (`calib::profile`): undoes
    /// the measured per-column gain/offset right after readout, the same
    /// place the engine applies it.
    correction: Option<crate::calib::ColumnCorrection>,
    scratch: PassScratch,
}

impl Default for NativeRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeRunner {
    pub fn new() -> NativeRunner {
        Self::with_calib(ColumnCalib::nominal(c::N_COLS))
    }

    /// A runner over a substrate with the given per-column fixed pattern
    /// (pair with [`set_correction`](NativeRunner::set_correction) to run
    /// profile-compensated).
    pub fn with_calib(calib: ColumnCalib) -> NativeRunner {
        NativeRunner {
            array: AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib),
            passes: 0,
            weight_loads: 0,
            noise: vec![0.0; c::N_COLS],
            correction: None,
            scratch: PassScratch::new(),
        }
    }

    /// Apply (or clear) a measured calibration correction.
    pub fn set_correction(
        &mut self,
        correction: Option<crate::calib::ColumnCorrection>,
    ) {
        if let Some(corr) = &correction {
            assert_eq!(corr.len(), c::N_COLS, "correction column count");
        }
        self.correction = correction;
    }

    /// The substrate this runner integrates on (tests/calibration).
    pub fn array_mut(&mut self) -> &mut AnalogArray {
        &mut self.array
    }

    /// Pack a logical tile into the physical array (zero-padded) and
    /// write it — one weight reconfiguration.  The packed buffer is the
    /// runner's scratch: only cells the previous tile wrote and this one
    /// will not overwrite are re-zeroed.
    fn load_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!((1..=c::K_LOGICAL).contains(&in_len));
        anyhow::ensure!((1..=c::N_COLS).contains(&out_len));
        anyhow::ensure!(w_tile.len() == in_len * out_len);
        let s = &mut self.scratch;
        for r in 0..s.w_rows {
            let row = &mut s.w_phys[r * c::N_COLS..r * c::N_COLS + s.w_cols];
            if r < in_len {
                if s.w_cols > out_len {
                    row[out_len..].fill(0);
                }
            } else {
                row.fill(0);
            }
        }
        for (r, w_row) in w_tile.chunks_exact(out_len).enumerate() {
            for (col, &w) in w_row.iter().enumerate() {
                s.w_phys[r * c::N_COLS + col] =
                    (w as i32).clamp(-c::W_MAX, c::W_MAX) as i8;
            }
        }
        s.w_rows = in_len;
        s.w_cols = out_len;
        self.array.load_weights(&s.w_phys);
        self.weight_loads += 1;
        Ok(())
    }

    /// One integration of the currently loaded tile (allocating wrapper
    /// over [`integrate_loaded_into`](NativeRunner::integrate_loaded_into)).
    fn integrate_loaded(
        &mut self,
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>> {
        let mut out = vec![0i16; out_len];
        self.integrate_loaded_into(in_len, out_len, x, scale, &mut out)?;
        Ok(out)
    }

    /// One integration of the currently loaded tile, written into `out`
    /// (`len == out_len`) — the allocation-free hot path.
    fn integrate_loaded_into(
        &mut self,
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
        out: &mut [i16],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(x.len() == in_len);
        anyhow::ensure!(out.len() == out_len);
        self.scratch.x_phys[..in_len].copy_from_slice(x);
        // Only rows the *previous* pass wrote beyond this pass's prefix
        // can hold stale events; the rest of the physical vector is
        // already zero, so nothing else needs a fill.
        if self.scratch.x_dirty > in_len {
            self.scratch.x_phys[in_len..self.scratch.x_dirty].fill(0);
        }
        self.scratch.x_dirty = in_len;
        self.array.integrate_into(
            &self.scratch.x_phys,
            scale,
            &self.noise,
            false,
            &mut self.scratch.acc,
            &mut self.scratch.adc,
        );
        self.passes += 1;
        out.copy_from_slice(&self.scratch.adc[..out_len]);
        if let Some(corr) = &self.correction {
            // Tiles occupy the column prefix, so the per-column correction
            // indexes line up with the tile output.
            corr.apply_i16(out);
        }
        Ok(())
    }

    /// Test hook: the physical activation scratch (zero-padding invariant).
    #[cfg(test)]
    fn scratch_x(&self) -> &[u8] {
        &self.scratch.x_phys
    }
}

impl PassRunner for NativeRunner {
    fn run_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>> {
        self.load_tile(w_tile, in_len, out_len)?;
        self.integrate_loaded(in_len, out_len, x, scale)
    }

    /// One weight write, `xs.len()` integrations.
    fn run_tile_batch(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        xs: &[Vec<u8>],
        scale: f32,
    ) -> anyhow::Result<Vec<Vec<i16>>> {
        self.load_tile(w_tile, in_len, out_len)?;
        xs.iter()
            .map(|x| self.integrate_loaded(in_len, out_len, x, scale))
            .collect()
    }

    /// One weight write, `batch` integrations, zero allocations.
    fn run_tile_batch_into(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        xs: &[u8],
        batch: usize,
        scale: f32,
        out: &mut [i16],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(xs.len() == batch * in_len, "batch input shape");
        anyhow::ensure!(out.len() == batch * out_len, "batch output shape");
        self.load_tile(w_tile, in_len, out_len)?;
        for (x, o) in
            xs.chunks_exact(in_len).zip(out.chunks_exact_mut(out_len))
        {
            self.integrate_loaded_into(in_len, out_len, x, scale, o)?;
        }
        Ok(())
    }

    fn passes(&self) -> usize {
        self.passes
    }

    fn weight_loads(&self) -> usize {
        self.weight_loads
    }
}

/// One linear layer of an arbitrary-size model.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Row-major `[in_dim][out_dim]` integer weights on the 6-bit grid.
    pub weights: Vec<f32>,
    pub scale: f32,
    /// Apply ReLU + >>RELU_SHIFT requantisation after this layer.
    pub relu_requant: bool,
}

/// Slice one chunk's weight tile out of a layer's row-major matrix.
fn slice_tile(layer: &LayerSpec, chunk: &super::partition::Chunk) -> Vec<f32> {
    let mut tile = Vec::new();
    slice_tile_into(layer, chunk, &mut tile);
    tile
}

/// [`slice_tile`] into a reusable buffer (resized; every cell written).
fn slice_tile_into(
    layer: &LayerSpec,
    chunk: &super::partition::Chunk,
    tile: &mut Vec<f32>,
) {
    let ol = chunk.out_len();
    tile.resize(chunk.in_len() * ol, 0.0);
    for (ri, r) in (chunk.in_start..chunk.in_end).enumerate() {
        for (ci, col) in (chunk.out_start..chunk.out_end).enumerate() {
            tile[ri * ol + ci] = layer.weights[r * layer.out_dim + col];
        }
    }
}

/// The digital inter-layer requantisation (SIMD-CPU semantics).
fn requantise(layer: &LayerSpec, raw: &[i32]) -> Vec<u8> {
    let mut acts = Vec::with_capacity(raw.len());
    requantise_into(layer, raw, &mut acts);
    acts
}

/// [`requantise`] into a reusable buffer (cleared then filled).  Purely
/// elementwise, so it applies unchanged to a flat batch-major buffer.
fn requantise_into(layer: &LayerSpec, raw: &[i32], acts: &mut Vec<u8>) {
    acts.clear();
    if layer.relu_requant {
        acts.extend(
            raw.iter()
                .map(|&v| ((v.max(0) >> c::RELU_SHIFT).min(c::X_MAX)) as u8),
        );
    } else {
        acts.extend(raw.iter().map(|&v| v.clamp(0, c::X_MAX) as u8));
    }
}

/// Execute one layer's plan: chunks -> tiles -> digital partial sums.
/// Partial sums accumulate in i32 (the SIMD CPUs' width) **before** any
/// nonlinearity, exactly like fc1's split blocks in the paper's Fig 6.
pub fn run_layer<R: PassRunner>(
    runner: &mut R,
    layer: &LayerSpec,
    plan: &Plan,
    x: &[u8],
) -> anyhow::Result<Vec<i32>> {
    anyhow::ensure!(x.len() == layer.in_dim, "input dim");
    anyhow::ensure!(
        plan.in_dim == layer.in_dim && plan.out_dim == layer.out_dim,
        "plan/layer mismatch"
    );
    let mut out = vec![0i32; layer.out_dim];
    for chunk in &plan.chunks {
        let tile = slice_tile(layer, chunk);
        let adc = runner.run_tile(
            &tile,
            chunk.in_len(),
            chunk.out_len(),
            &x[chunk.in_start..chunk.in_end],
            layer.scale,
        )?;
        for (ci, &v) in adc.iter().enumerate() {
            out[chunk.out_start + ci] += v as i32; // digital partial sum
        }
    }
    Ok(out)
}

/// Reusable buffers for the flat batch-major executor path (DESIGN.md
/// §17).  One instance amortises every per-chunk and per-layer allocation
/// of [`run_layer_batch_into`] / [`run_model_batch_flat`] across an
/// arbitrary number of calls.
#[derive(Default)]
pub struct BatchScratch {
    /// Current chunk's weight tile (`in_len × out_len`, row-major).
    tile: Vec<f32>,
    /// Batch-major activation slices for the current chunk (`B × in_len`).
    xs: Vec<u8>,
    /// Batch-major ADC outputs for the current chunk (`B × out_len`).
    adc: Vec<i16>,
    /// Batch-major requantised inter-layer activations (`B × dim`).
    acts: Vec<u8>,
}

/// Batched layer execution: every chunk's weight tile is sliced and
/// written **once** and integrated against all `xs.len()` activation
/// vectors (`run_layer` re-sliced and re-wrote it per sample).  Per-sample
/// results are bit-identical to `run_layer`.  Thin nested-`Vec` wrapper
/// over [`run_layer_batch_into`].
pub fn run_layer_batch<R: PassRunner>(
    runner: &mut R,
    layer: &LayerSpec,
    plan: &Plan,
    xs: &[Vec<u8>],
) -> anyhow::Result<Vec<Vec<i32>>> {
    anyhow::ensure!(!xs.is_empty(), "empty batch");
    for x in xs {
        anyhow::ensure!(x.len() == layer.in_dim, "input dim");
    }
    let mut flat = Vec::with_capacity(xs.len() * layer.in_dim);
    for x in xs {
        flat.extend_from_slice(x);
    }
    let mut out = Vec::new();
    let mut scratch = BatchScratch::default();
    run_layer_batch_into(
        runner,
        layer,
        plan,
        &flat,
        xs.len(),
        &mut out,
        &mut scratch,
    )?;
    Ok(out.chunks_exact(layer.out_dim).map(|o| o.to_vec()).collect())
}

/// Flat batch-major layer execution: `xs` is `batch × in_dim` row-major,
/// `out` is resized to `batch × out_dim` and holds the raw i32 partial
/// sums.  All intermediate buffers live in `scratch`, so steady-state
/// calls allocate nothing.  The inner accumulation walks contiguous
/// per-sample rows of both the ADC buffer and the output, which is the
/// vectorisation-friendly layout (no strided gather per column).
pub fn run_layer_batch_into<R: PassRunner>(
    runner: &mut R,
    layer: &LayerSpec,
    plan: &Plan,
    xs: &[u8],
    batch: usize,
    out: &mut Vec<i32>,
    scratch: &mut BatchScratch,
) -> anyhow::Result<()> {
    anyhow::ensure!(batch > 0, "empty batch");
    anyhow::ensure!(
        plan.in_dim == layer.in_dim && plan.out_dim == layer.out_dim,
        "plan/layer mismatch"
    );
    anyhow::ensure!(xs.len() == batch * layer.in_dim, "input dim");
    out.clear();
    out.resize(batch * layer.out_dim, 0);
    for chunk in &plan.chunks {
        let (il, ol) = (chunk.in_len(), chunk.out_len());
        slice_tile_into(layer, chunk, &mut scratch.tile);
        scratch.xs.resize(batch * il, 0);
        for s in 0..batch {
            let row = s * layer.in_dim;
            scratch.xs[s * il..(s + 1) * il].copy_from_slice(
                &xs[row + chunk.in_start..row + chunk.in_end],
            );
        }
        scratch.adc.resize(batch * ol, 0);
        runner.run_tile_batch_into(
            &scratch.tile,
            il,
            ol,
            &scratch.xs,
            batch,
            layer.scale,
            &mut scratch.adc,
        )?;
        for s in 0..batch {
            let row = s * layer.out_dim;
            let dst = &mut out[row + chunk.out_start..row + chunk.out_end];
            let src = &scratch.adc[s * ol..(s + 1) * ol];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d += v as i32; // digital partial sum
            }
        }
    }
    Ok(())
}

/// Per-layer execution plans of a model, partitioned **once** and reused
/// across samples and batches (`run_model` used to re-partition every
/// layer on every call — once per sample under serving load).
#[derive(Debug, Clone)]
pub struct ModelPlan {
    plans: Vec<Plan>,
}

impl ModelPlan {
    pub fn of(layers: &[LayerSpec]) -> anyhow::Result<ModelPlan> {
        anyhow::ensure!(!layers.is_empty(), "empty model");
        let plans: Vec<Plan> = layers
            .iter()
            .map(|l| partition(l.in_dim, l.out_dim, c::N_HALVES))
            .collect();
        for plan in &plans {
            plan.check_invariants().map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(ModelPlan { plans })
    }

    pub fn plans(&self) -> &[Plan] {
        &self.plans
    }

    /// Integration cycles per sample.
    pub fn passes_per_sample(&self) -> usize {
        self.plans.iter().map(|p| p.passes()).sum()
    }
}

/// Execute a stack of layers end to end (5-bit activations between layers).
pub fn run_model<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    input: &[u8],
) -> anyhow::Result<Vec<i32>> {
    let plan = ModelPlan::of(layers)?;
    run_model_planned(runner, layers, &plan, input)
}

/// `run_model` against a pre-computed [`ModelPlan`].
pub fn run_model_planned<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    plan: &ModelPlan,
    input: &[u8],
) -> anyhow::Result<Vec<i32>> {
    anyhow::ensure!(layers.len() == plan.plans.len(), "plan/model mismatch");
    let mut acts: Vec<u8> = input.to_vec();
    let mut last_raw: Vec<i32> = acts.iter().map(|&a| a as i32).collect();
    for (layer, lplan) in layers.iter().zip(&plan.plans) {
        let raw = run_layer(runner, layer, lplan, &acts)?;
        acts = requantise(layer, &raw);
        last_raw = raw;
    }
    Ok(last_raw)
}

/// Batched model execution: for every layer, each weight tile is written
/// once per *batch* instead of once per sample.  Guarantee (property
/// tested): `run_model_batch(..)[i]` is bit-identical to
/// `run_model(.., inputs[i])` for every `i`.  Thin nested-`Vec` wrapper
/// over [`run_model_batch_flat`].
pub fn run_model_batch<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    plan: &ModelPlan,
    inputs: &[Vec<u8>],
) -> anyhow::Result<Vec<Vec<i32>>> {
    anyhow::ensure!(!inputs.is_empty(), "empty batch");
    anyhow::ensure!(!layers.is_empty(), "empty model");
    anyhow::ensure!(layers.len() == plan.plans.len(), "plan/model mismatch");
    let in_dim = layers[0].in_dim;
    for x in inputs {
        anyhow::ensure!(x.len() == in_dim, "input dim");
    }
    let mut flat = Vec::with_capacity(inputs.len() * in_dim);
    for x in inputs {
        flat.extend_from_slice(x);
    }
    let mut out = Vec::new();
    let mut scratch = BatchScratch::default();
    run_model_batch_flat(
        runner,
        layers,
        plan,
        &flat,
        inputs.len(),
        &mut out,
        &mut scratch,
    )?;
    let out_dim = match layers.last() {
        Some(l) => l.out_dim,
        None => unreachable!("guarded by the empty-model ensure above"),
    };
    Ok(out.chunks_exact(out_dim).map(|o| o.to_vec()).collect())
}

/// Flat batch-major model execution (DESIGN.md §17): `inputs` is `batch ×
/// layers[0].in_dim` row-major, `out` is resized to `batch ×
/// last.out_dim` and holds the last layer's raw i32 sums.  With a warm
/// `scratch` the whole forward pass allocates nothing — this is the
/// serving/bench hot path.
pub fn run_model_batch_flat<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    plan: &ModelPlan,
    inputs: &[u8],
    batch: usize,
    out: &mut Vec<i32>,
    scratch: &mut BatchScratch,
) -> anyhow::Result<()> {
    anyhow::ensure!(batch > 0, "empty batch");
    anyhow::ensure!(!layers.is_empty(), "empty model");
    anyhow::ensure!(layers.len() == plan.plans.len(), "plan/model mismatch");
    anyhow::ensure!(inputs.len() == batch * layers[0].in_dim, "input dim");
    // The activation buffer is taken out of the scratch for the loop so
    // the layer call can borrow the rest of the scratch mutably; it is
    // put back (capacity intact) before returning.
    let mut acts = std::mem::take(&mut scratch.acts);
    for (i, (layer, lplan)) in layers.iter().zip(&plan.plans).enumerate() {
        let xs: &[u8] = if i == 0 { inputs } else { &acts };
        run_layer_batch_into(runner, layer, lplan, xs, batch, out, scratch)?;
        if i + 1 < layers.len() {
            // Elementwise, so the flat buffer requantises in one sweep.
            requantise_into(layer, out, &mut acts);
        }
    }
    scratch.acts = acts;
    Ok(())
}

/// Cost model: integration cycles + simulated chip time for a layer stack
/// (paper §III-A: oversize networks pay reconfiguration/serialisation).
pub fn cost_of(layers: &[(usize, usize)]) -> (usize, f64) {
    let passes: usize = layers
        .iter()
        .map(|&(i, o)| partition(i, o, c::N_HALVES).passes())
        .sum();
    let time_us = passes as f64 * c::INTEGRATION_CYCLE_US;
    (passes, time_us)
}

/// Chip-time cost of classifying a batch of `batch` samples.
#[derive(Debug, Clone, Copy)]
pub struct BatchCost {
    pub batch: usize,
    /// Integration cycles over the whole batch.
    pub passes: usize,
    /// Weight reconfigurations over the whole batch (once per tile).
    pub weight_loads: usize,
    /// Total simulated chip time for the batch [µs].
    pub total_us: f64,
}

impl BatchCost {
    pub fn per_sample_us(&self) -> f64 {
        self.total_us / self.batch as f64
    }
}

/// Batched cost model: integration work scales with the batch, but each
/// tile's weight write is paid once per batch — so per-sample cost
/// decreases monotonically in `batch` toward the pure-integration floor.
pub fn cost_of_batch(layers: &[(usize, usize)], batch: usize) -> BatchCost {
    assert!(batch > 0, "batch must be positive");
    let tiles: usize = layers
        .iter()
        .map(|&(i, o)| partition(i, o, c::N_HALVES).passes())
        .sum();
    let passes = tiles * batch;
    let total_us = tiles as f64 * c::WEIGHT_WRITE_US
        + passes as f64 * c::INTEGRATION_CYCLE_US;
    BatchCost { batch, passes, weight_loads: tiles, total_us }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck;
    use crate::util::rng::SplitMix64;

    fn rand_layer(
        rng: &mut SplitMix64,
        in_dim: usize,
        out_dim: usize,
        relu: bool,
    ) -> LayerSpec {
        LayerSpec {
            in_dim,
            out_dim,
            weights: (0..in_dim * out_dim)
                .map(|_| (rng.below(2 * c::W_MAX as u64 + 1) as i32
                    - c::W_MAX) as f32)
                .collect(),
            scale: 0.002,
            relu_requant: relu,
        }
    }

    /// Float reference for a single layer in the linear regime.
    fn dense_ref(layer: &LayerSpec, x: &[u8]) -> Vec<f64> {
        let mut out = vec![0.0f64; layer.out_dim];
        for (r, &xv) in x.iter().enumerate() {
            for col in 0..layer.out_dim {
                out[col] += xv as f64
                    * layer.weights[r * layer.out_dim + col] as f64;
            }
        }
        out
    }

    #[test]
    fn single_chip_layer_matches_reference() {
        let mut rng = SplitMix64::new(1);
        let layer = rand_layer(&mut rng, 200, 100, false);
        let x: Vec<u8> = (0..200).map(|_| rng.below(4) as u8).collect();
        let plan = partition(200, 100, 2);
        let mut runner = NativeRunner::new();
        let got = run_layer(&mut runner, &layer, &plan, &x).unwrap();
        let want = dense_ref(&layer, &x);
        for (g, w) in got.iter().zip(&want) {
            let expect = (w * layer.scale as f64).round().clamp(-128.0, 127.0);
            assert!(
                (*g as f64 - expect).abs() <= 1.0,
                "got {g} want {expect}"
            );
        }
        assert_eq!(runner.passes(), 1);
    }

    #[test]
    fn oversize_layer_partial_sums() {
        // 600 inputs -> 3 input tiles; digital accumulation must match the
        // direct dense product in the linear regime.
        let mut rng = SplitMix64::new(2);
        let layer = rand_layer(&mut rng, 600, 300, false);
        // Small activations keep each *partial* sum inside the ADC range.
        let x: Vec<u8> = (0..600).map(|_| rng.below(2) as u8).collect();
        let plan = partition(600, 300, 2);
        let mut runner = NativeRunner::new();
        let got = run_layer(&mut runner, &layer, &plan, &x).unwrap();
        assert_eq!(runner.passes(), plan.passes());
        let want = dense_ref(&layer, &x);
        let mut worst = 0.0f64;
        for (g, w) in got.iter().zip(&want) {
            let expect = w * layer.scale as f64;
            worst = worst.max((*g as f64 - expect).abs());
        }
        // Each tile rounds independently: error <= 0.5 LSB per input tile.
        assert!(worst <= 3.0 * 0.5 + 1e-9, "worst {worst}");
    }

    #[test]
    fn multi_layer_stack_runs() {
        let mut rng = SplitMix64::new(3);
        let layers = vec![
            rand_layer(&mut rng, 300, 400, true),
            rand_layer(&mut rng, 400, 150, true),
            rand_layer(&mut rng, 150, 10, false),
        ];
        let x: Vec<u8> = (0..300).map(|_| rng.below(8) as u8).collect();
        let mut runner = NativeRunner::new();
        let out = run_model(&mut runner, &layers, &x).unwrap();
        assert_eq!(out.len(), 10);
        // 300x400: 2x2=4 chunks; 400x150: 2 chunks; 150x10: 1 chunk.
        assert_eq!(runner.passes(), 4 + 2 + 1);
    }

    #[test]
    fn executor_equivalence_property() {
        propcheck::check("executor_matches_dense", 12, 0xFACE, |g| {
            let in_dim = g.usize_in(1, 520);
            let out_dim = g.usize_in(1, 300);
            let mut rng = SplitMix64::new(g.rng.next_u64());
            let layer = rand_layer(&mut rng, in_dim, out_dim, false);
            let x: Vec<u8> =
                (0..in_dim).map(|_| rng.below(2) as u8).collect();
            let plan = partition(in_dim, out_dim, 2);
            let mut runner = NativeRunner::new();
            let got = run_layer(&mut runner, &layer, &plan, &x)
                .map_err(|e| e.to_string())?;
            let want = dense_ref(&layer, &x);
            let tiles = in_dim.div_ceil(c::K_LOGICAL) as f64;
            for (i, (gv, wv)) in got.iter().zip(&want).enumerate() {
                let expect = wv * layer.scale as f64;
                // Only check columns whose exact value stays linear.
                if expect.abs() < 100.0 {
                    prop_assert!(
                        (*gv as f64 - expect).abs() <= 0.5 * tiles + 1e-6,
                        "col {i}: got {gv} want {expect}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn profile_correction_recovers_nominal_layer() {
        use crate::asic::array::ColumnCalib;
        use crate::calib::ColumnCorrection;

        let mut rng = SplitMix64::new(31);
        let layer = rand_layer(&mut rng, 200, 120, false);
        let x: Vec<u8> = (0..200).map(|_| rng.below(3) as u8).collect();
        let plan = partition(200, 120, 2);
        let mut nominal = NativeRunner::new();
        let want = run_layer(&mut nominal, &layer, &plan, &x).unwrap();

        let mut fpn_rng = SplitMix64::new(77);
        let calib = ColumnCalib::fixed_pattern(c::N_COLS, &mut fpn_rng);
        // Uncompensated fixed pattern: raw deviation from the ideal.
        let mut raw = NativeRunner::with_calib(calib.clone());
        let got_raw = run_layer(&mut raw, &layer, &plan, &x).unwrap();
        // Measure the pattern (noise-free) and run compensated.
        let mut comp = NativeRunner::with_calib(calib);
        let m = crate::asic::calib::calibrate_half_with(
            comp.array_mut(),
            &mut SplitMix64::new(5),
            16,
            0.0,
        );
        comp.set_correction(Some(ColumnCorrection::from_measured(
            &m.gain_est,
            &m.offset_est,
        )));
        let got = run_layer(&mut comp, &layer, &plan, &x).unwrap();

        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 3,
                "col {i}: compensated {g} vs nominal {w}"
            );
        }
        let dev = |a: &[i32]| -> i64 {
            a.iter().zip(&want).map(|(v, w)| (v - w).abs() as i64).sum()
        };
        assert!(
            dev(&got) <= dev(&got_raw),
            "compensation must not be worse than the raw fixed pattern \
             ({} vs {})",
            dev(&got),
            dev(&got_raw)
        );
    }

    #[test]
    fn cost_model_scales() {
        let (p_small, t_small) = cost_of(&[(256, 256)]);
        assert_eq!(p_small, 1);
        assert!((t_small - c::INTEGRATION_CYCLE_US).abs() < 1e-9);
        let (p_big, _) = cost_of(&[(1024, 1024)]);
        assert_eq!(p_big, 16);
        // Paper §V scale: a 10M-parameter model is time-multiplexable.
        let (p_huge, t_huge) = cost_of(&[(3000, 3000), (3000, 1000)]);
        assert!(p_huge > 100);
        assert!(t_huge > 500.0);
    }

    #[test]
    fn batch_cost_amortises_weight_writes() {
        let shapes = [(600usize, 300usize), (300, 10)];
        let c1 = cost_of_batch(&shapes, 1);
        // 600x300: 3x2 = 6 tiles; 300x10: 2 tiles.
        assert_eq!(c1.weight_loads, 8);
        assert_eq!(c1.passes, 8);
        let mut prev = c1.per_sample_us();
        for b in [2usize, 4, 8, 16, 32] {
            let cb = cost_of_batch(&shapes, b);
            assert_eq!(cb.weight_loads, 8, "loads are per batch, not sample");
            assert_eq!(cb.passes, 8 * b, "integrations are per sample");
            let per = cb.per_sample_us();
            assert!(per < prev, "B={b}: {per} !< {prev}");
            prev = per;
        }
        // The floor is the pure-integration cost.
        let floor = 8.0 * c::INTEGRATION_CYCLE_US;
        assert!(prev > floor);
        assert!(prev - floor < 8.0 * c::WEIGHT_WRITE_US / 32.0 + 1e-9);
    }

    #[test]
    fn native_runner_batch_loads_weights_once() {
        let mut rng = SplitMix64::new(11);
        let layer = rand_layer(&mut rng, 600, 300, false);
        let plan = partition(600, 300, 2);
        let xs: Vec<Vec<u8>> = (0..4)
            .map(|_| (0..600).map(|_| rng.below(2) as u8).collect())
            .collect();
        let mut runner = NativeRunner::new();
        let out = run_layer_batch(&mut runner, &layer, &plan, &xs).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(runner.passes(), 4 * plan.passes());
        assert_eq!(runner.weight_loads(), plan.passes(), "one write per tile");
    }

    #[test]
    fn stale_x_suffix_zeroed_after_shorter_pass() {
        // Regression (ISSUE 10 satellite): a short-input pass after a
        // long-input pass must see zeros in the physical tail.  The
        // output cannot reveal a stale tail directly — `load_tile` keeps
        // the tail *weights* zero too — so this checks the zero-padding
        // invariant on the scratch itself.
        let mut runner = NativeRunner::new();
        let w_long = vec![1.0f32; c::K_LOGICAL];
        let x_long = vec![3u8; c::K_LOGICAL];
        runner.run_tile(&w_long, c::K_LOGICAL, 1, &x_long, 1.0).unwrap();
        assert!(runner.scratch_x().iter().all(|&v| v == 3));
        let got = runner
            .run_tile(&[1.0, 1.0, 1.0, 1.0], 4, 1, &[7, 7, 7, 7], 1.0)
            .unwrap();
        assert_eq!(&runner.scratch_x()[..4], &[7, 7, 7, 7]);
        assert!(
            runner.scratch_x()[4..].iter().all(|&v| v == 0),
            "stale suffix survived the shorter pass"
        );
        // And the conversion sees only the 4 live rows.
        assert_eq!(got, vec![28]);
    }

    #[test]
    fn pass_results_independent_of_previous_pass_length() {
        // Belt and braces for the invariant test above: a reused runner
        // and a fresh runner must produce identical tiles regardless of
        // what earlier (larger) passes left in the scratch.
        let mut rng = SplitMix64::new(0xD1);
        let w_big: Vec<f32> = (0..c::K_LOGICAL * 8)
            .map(|_| (rng.below(13) as i32 - 6) as f32)
            .collect();
        let x_big: Vec<u8> =
            (0..c::K_LOGICAL).map(|_| rng.below(32) as u8).collect();
        let w_small: Vec<f32> =
            (0..6 * 3).map(|_| (rng.below(13) as i32 - 6) as f32).collect();
        let x_small: Vec<u8> = (0..6).map(|_| rng.below(32) as u8).collect();
        let mut reused = NativeRunner::new();
        reused.run_tile(&w_big, c::K_LOGICAL, 8, &x_big, 0.05).unwrap();
        let got = reused.run_tile(&w_small, 6, 3, &x_small, 0.05).unwrap();
        let mut fresh = NativeRunner::new();
        let want = fresh.run_tile(&w_small, 6, 3, &x_small, 0.05).unwrap();
        assert_eq!(got, want);
    }

    /// The pre-scratch (PR ≤ 9) native runner, retained verbatim as the
    /// golden reference for the equivalence property: every pass
    /// allocates `x_phys`, the integrate output, and a truncated copy —
    /// but its arithmetic is the specification the scratch path must
    /// reproduce bit for bit.
    struct ReferenceRunner {
        array: AnalogArray,
        passes: usize,
        weight_loads: usize,
        noise: Vec<f32>,
        correction: Option<crate::calib::ColumnCorrection>,
    }

    impl ReferenceRunner {
        fn with_calib(calib: ColumnCalib) -> ReferenceRunner {
            ReferenceRunner {
                array: AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib),
                passes: 0,
                weight_loads: 0,
                noise: vec![0.0; c::N_COLS],
                correction: None,
            }
        }

        fn load_tile(
            &mut self,
            w_tile: &[f32],
            in_len: usize,
            out_len: usize,
        ) -> anyhow::Result<()> {
            anyhow::ensure!((1..=c::K_LOGICAL).contains(&in_len));
            anyhow::ensure!((1..=c::N_COLS).contains(&out_len));
            anyhow::ensure!(w_tile.len() == in_len * out_len);
            let mut w_phys = vec![0i8; c::K_LOGICAL * c::N_COLS];
            for (r, w_row) in w_tile.chunks_exact(out_len).enumerate() {
                for (col, &w) in w_row.iter().enumerate() {
                    w_phys[r * c::N_COLS + col] =
                        (w as i32).clamp(-c::W_MAX, c::W_MAX) as i8;
                }
            }
            self.array.load_weights(&w_phys);
            self.weight_loads += 1;
            Ok(())
        }

        fn integrate_loaded(
            &mut self,
            in_len: usize,
            out_len: usize,
            x: &[u8],
            scale: f32,
        ) -> anyhow::Result<Vec<i16>> {
            anyhow::ensure!(x.len() == in_len);
            let mut x_phys = vec![0u8; c::K_LOGICAL];
            x_phys[..in_len].copy_from_slice(x);
            let out =
                self.array.integrate(&x_phys, scale, &self.noise, false);
            self.passes += 1;
            let mut out = out[..out_len].to_vec();
            if let Some(corr) = &self.correction {
                corr.apply_i16(&mut out);
            }
            Ok(out)
        }
    }

    impl PassRunner for ReferenceRunner {
        fn run_tile(
            &mut self,
            w_tile: &[f32],
            in_len: usize,
            out_len: usize,
            x: &[u8],
            scale: f32,
        ) -> anyhow::Result<Vec<i16>> {
            self.load_tile(w_tile, in_len, out_len)?;
            self.integrate_loaded(in_len, out_len, x, scale)
        }

        fn run_tile_batch(
            &mut self,
            w_tile: &[f32],
            in_len: usize,
            out_len: usize,
            xs: &[Vec<u8>],
            scale: f32,
        ) -> anyhow::Result<Vec<Vec<i16>>> {
            self.load_tile(w_tile, in_len, out_len)?;
            xs.iter()
                .map(|x| self.integrate_loaded(in_len, out_len, x, scale))
                .collect()
        }

        fn passes(&self) -> usize {
            self.passes
        }

        fn weight_loads(&self) -> usize {
            self.weight_loads
        }
    }

    /// The pre-scratch `run_layer_batch`, retained verbatim (nested Vecs,
    /// per-chunk slice copies) for the same reason as [`ReferenceRunner`].
    fn reference_run_layer_batch(
        runner: &mut ReferenceRunner,
        layer: &LayerSpec,
        plan: &Plan,
        xs: &[Vec<u8>],
    ) -> anyhow::Result<Vec<Vec<i32>>> {
        anyhow::ensure!(!xs.is_empty(), "empty batch");
        let mut out = vec![vec![0i32; layer.out_dim]; xs.len()];
        for chunk in &plan.chunks {
            let tile = slice_tile(layer, chunk);
            let slices: Vec<Vec<u8>> = xs
                .iter()
                .map(|x| x[chunk.in_start..chunk.in_end].to_vec())
                .collect();
            let adcs = runner.run_tile_batch(
                &tile,
                chunk.in_len(),
                chunk.out_len(),
                &slices,
                layer.scale,
            )?;
            for (sample, adc) in out.iter_mut().zip(&adcs) {
                for (ci, &v) in adc.iter().enumerate() {
                    sample[chunk.out_start + ci] += v as i32;
                }
            }
        }
        Ok(out)
    }

    /// ISSUE 10 acceptance property: the scratch-buffer executor is
    /// bit-identical to the retained reference — i16 tile outputs, raw
    /// i32 partial sums, u8 requantised activations, and accounting —
    /// across random shapes, partitions, batch sizes, correction on/off,
    /// and noise on/off.
    #[test]
    fn scratch_executor_matches_reference_property() {
        use crate::calib::ColumnCorrection;
        propcheck::check("scratch_vs_reference", 10, 0x5CA7C4, |g| {
            let mut rng = SplitMix64::new(g.rng.next_u64());
            let d0 = g.usize_in(1, 520);
            let d1 = g.usize_in(1, 300);
            let d2 = g.usize_in(1, 40);
            let layers = vec![
                rand_layer(&mut rng, d0, d1, true),
                rand_layer(&mut rng, d1, d2, false),
            ];
            let batch = g.usize_in(1, 5);
            let inputs: Vec<Vec<u8>> = (0..batch)
                .map(|_| (0..d0).map(|_| rng.below(32) as u8).collect())
                .collect();
            let fpn_on = g.rng.next_u64() % 2 == 0;
            let noise_on = g.rng.next_u64() % 2 == 0;
            let corr_on = g.rng.next_u64() % 2 == 0;
            let calib = if fpn_on {
                ColumnCalib::fixed_pattern(c::N_COLS, &mut rng)
            } else {
                ColumnCalib::nominal(c::N_COLS)
            };
            let mut new_r = NativeRunner::with_calib(calib.clone());
            let mut ref_r = ReferenceRunner::with_calib(calib);
            if noise_on {
                let noise: Vec<f32> = (0..c::N_COLS)
                    .map(|_| (0.7 * rng.gauss()) as f32)
                    .collect();
                new_r.noise.copy_from_slice(&noise);
                ref_r.noise = noise;
            }
            if corr_on {
                let gain: Vec<f32> = (0..c::N_COLS)
                    .map(|_| (1.0 + 0.05 * rng.gauss()) as f32)
                    .collect();
                let offset: Vec<f32> = (0..c::N_COLS)
                    .map(|_| (2.0 * rng.gauss()) as f32)
                    .collect();
                let corr = ColumnCorrection::from_measured(&gain, &offset);
                new_r.set_correction(Some(corr.clone()));
                ref_r.correction = Some(corr);
            }
            let plan = ModelPlan::of(&layers).map_err(|e| e.to_string())?;
            // Layer by layer: raw sums and requantised activations must
            // agree at every boundary, not just at the model output.
            let mut acts_new = inputs.clone();
            let mut acts_ref = inputs;
            for (li, (layer, lplan)) in
                layers.iter().zip(plan.plans()).enumerate()
            {
                let raw_new =
                    run_layer_batch(&mut new_r, layer, lplan, &acts_new)
                        .map_err(|e| e.to_string())?;
                let raw_ref = reference_run_layer_batch(
                    &mut ref_r, layer, lplan, &acts_ref,
                )
                .map_err(|e| e.to_string())?;
                prop_assert!(
                    raw_new == raw_ref,
                    "layer {li}: raw sums diverge (new {:?} ref {:?})",
                    &raw_new[0][..raw_new[0].len().min(8)],
                    &raw_ref[0][..raw_ref[0].len().min(8)]
                );
                acts_new =
                    raw_new.iter().map(|r| requantise(layer, r)).collect();
                acts_ref =
                    raw_ref.iter().map(|r| requantise(layer, r)).collect();
                prop_assert!(
                    acts_new == acts_ref,
                    "layer {li}: requantised activations diverge"
                );
            }
            // Direct i16 parity on a single tile (the raw-sum check above
            // only sees i16s through the digital accumulation).
            let chunk = &plan.plans()[0].chunks[0];
            let tile = slice_tile(&layers[0], chunk);
            let x0: Vec<u8> = vec![1; chunk.in_len()];
            let t_new = new_r
                .run_tile(
                    &tile,
                    chunk.in_len(),
                    chunk.out_len(),
                    &x0,
                    layers[0].scale,
                )
                .map_err(|e| e.to_string())?;
            let t_ref = ref_r
                .run_tile(
                    &tile,
                    chunk.in_len(),
                    chunk.out_len(),
                    &x0,
                    layers[0].scale,
                )
                .map_err(|e| e.to_string())?;
            prop_assert!(t_new == t_ref, "single-tile i16 outputs diverge");
            // Accounting parity: same passes, same weight writes.
            prop_assert!(
                new_r.passes() == ref_r.passes()
                    && new_r.weight_loads() == ref_r.weight_loads(),
                "accounting diverges: {}/{} vs {}/{}",
                new_r.passes(),
                new_r.weight_loads(),
                ref_r.passes(),
                ref_r.weight_loads()
            );
            Ok(())
        });
    }

    /// Acceptance property: `run_model_batch(B)[i] == run_model(sample_i)`
    /// bit-for-bit, for random layer stacks and batch sizes.
    #[test]
    fn model_batch_matches_sequential_property() {
        propcheck::check("run_model_batch_parity", 10, 0xBA7C4, |g| {
            let mut rng = SplitMix64::new(g.rng.next_u64());
            let d0 = g.usize_in(1, 400);
            let d1 = g.usize_in(1, 300);
            let d2 = g.usize_in(1, 64);
            let layers = vec![
                rand_layer(&mut rng, d0, d1, true),
                rand_layer(&mut rng, d1, d2, false),
            ];
            let batch = g.usize_in(1, 6);
            let inputs: Vec<Vec<u8>> = (0..batch)
                .map(|_| (0..d0).map(|_| rng.below(8) as u8).collect())
                .collect();
            let plan = ModelPlan::of(&layers).map_err(|e| e.to_string())?;
            let mut batch_runner = NativeRunner::new();
            let got =
                run_model_batch(&mut batch_runner, &layers, &plan, &inputs)
                    .map_err(|e| e.to_string())?;
            for (i, input) in inputs.iter().enumerate() {
                let mut seq_runner = NativeRunner::new();
                let want = run_model(&mut seq_runner, &layers, input)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    got[i] == want,
                    "sample {i}: batch {:?} != sequential {:?}",
                    &got[i][..want.len().min(8)],
                    &want[..want.len().min(8)]
                );
            }
            // Amortisation: the batch path writes each tile once.
            prop_assert!(
                batch_runner.weight_loads() == plan.passes_per_sample(),
                "weight loads {} != tiles {}",
                batch_runner.weight_loads(),
                plan.passes_per_sample()
            );
            Ok(())
        });
    }
}
