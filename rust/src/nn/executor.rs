//! Multi-chunk plan executor — the hxtorch "Hardware Resources" contract
//! (paper §II-D): arbitrary-size linear layers run on the fixed-size analog
//! substrate by executing their partitioned [`Plan`] chunk by chunk,
//! accumulating partial sums digitally (SIMD CPUs) and requantising between
//! layers.  Paper §V: "rate-based stateless operation ... allows for
//! multiplexing hardware resources in time and therefore has the advantage
//! of supporting arbitrarily large model sizes".
//!
//! The executor drives any [`PassRunner`] — the native analog array model
//! here, the PJRT artifact in the engine — and is validated against a float
//! reference on random layer stacks (quantisation-aware, see tests).

use crate::asic::array::{AnalogArray, ColumnCalib};
use crate::asic::consts as c;

use super::partition::{partition, Plan};

/// Anything that can run one physical integration cycle of a chip-sized
/// weight tile: `x` (5-bit activations, len == chunk in_len) against a
/// `in_len x out_len` tile, returning signed ADC counts.
pub trait PassRunner {
    fn run_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>>;

    /// Batched variant of [`run_tile`](PassRunner::run_tile): integrate
    /// every activation vector in `xs` against the *same* weight tile.
    /// Backends override this to write the tile once and loop only the
    /// integration (the hxtorch batching lever); the default degrades to
    /// one reconfiguration per sample, so results are bit-identical
    /// either way.
    fn run_tile_batch(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        xs: &[Vec<u8>],
        scale: f32,
    ) -> anyhow::Result<Vec<Vec<i16>>> {
        xs.iter()
            .map(|x| self.run_tile(w_tile, in_len, out_len, x, scale))
            .collect()
    }

    /// Integration cycles executed so far (for cost accounting).
    fn passes(&self) -> usize;

    /// Weight reconfigurations (tile writes) so far.  Backends that do
    /// not track reconfiguration pay one write per pass.
    fn weight_loads(&self) -> usize {
        self.passes()
    }
}

/// Native-model runner: loads each tile into an analog array half and
/// integrates (noise-free by default; the engine path carries noise).
pub struct NativeRunner {
    array: AnalogArray,
    passes: usize,
    weight_loads: usize,
    pub noise: Vec<f32>,
    /// Optional post-ADC calibration correction (`calib::profile`): undoes
    /// the measured per-column gain/offset right after readout, the same
    /// place the engine applies it.
    correction: Option<crate::calib::ColumnCorrection>,
}

impl Default for NativeRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeRunner {
    pub fn new() -> NativeRunner {
        Self::with_calib(ColumnCalib::nominal(c::N_COLS))
    }

    /// A runner over a substrate with the given per-column fixed pattern
    /// (pair with [`set_correction`](NativeRunner::set_correction) to run
    /// profile-compensated).
    pub fn with_calib(calib: ColumnCalib) -> NativeRunner {
        NativeRunner {
            array: AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib),
            passes: 0,
            weight_loads: 0,
            noise: vec![0.0; c::N_COLS],
            correction: None,
        }
    }

    /// Apply (or clear) a measured calibration correction.
    pub fn set_correction(
        &mut self,
        correction: Option<crate::calib::ColumnCorrection>,
    ) {
        if let Some(corr) = &correction {
            assert_eq!(corr.len(), c::N_COLS, "correction column count");
        }
        self.correction = correction;
    }

    /// The substrate this runner integrates on (tests/calibration).
    pub fn array_mut(&mut self) -> &mut AnalogArray {
        &mut self.array
    }

    /// Pack a logical tile into the physical array (zero-padded) and
    /// write it — one weight reconfiguration.
    fn load_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!((1..=c::K_LOGICAL).contains(&in_len));
        anyhow::ensure!((1..=c::N_COLS).contains(&out_len));
        anyhow::ensure!(w_tile.len() == in_len * out_len);
        let mut w_phys = vec![0i8; c::K_LOGICAL * c::N_COLS];
        for (r, w_row) in w_tile.chunks_exact(out_len).enumerate() {
            for (col, &w) in w_row.iter().enumerate() {
                w_phys[r * c::N_COLS + col] =
                    (w as i32).clamp(-c::W_MAX, c::W_MAX) as i8;
            }
        }
        self.array.load_weights(&w_phys);
        self.weight_loads += 1;
        Ok(())
    }

    /// One integration of the currently loaded tile.
    fn integrate_loaded(
        &mut self,
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>> {
        anyhow::ensure!(x.len() == in_len);
        let mut x_phys = vec![0u8; c::K_LOGICAL];
        x_phys[..in_len].copy_from_slice(x);
        let out = self.array.integrate(&x_phys, scale, &self.noise, false);
        self.passes += 1;
        let mut out = out[..out_len].to_vec();
        if let Some(corr) = &self.correction {
            // Tiles occupy the column prefix, so the per-column correction
            // indexes line up with the tile output.
            corr.apply_i16(&mut out);
        }
        Ok(out)
    }
}

impl PassRunner for NativeRunner {
    fn run_tile(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        x: &[u8],
        scale: f32,
    ) -> anyhow::Result<Vec<i16>> {
        self.load_tile(w_tile, in_len, out_len)?;
        self.integrate_loaded(in_len, out_len, x, scale)
    }

    /// One weight write, `xs.len()` integrations.
    fn run_tile_batch(
        &mut self,
        w_tile: &[f32],
        in_len: usize,
        out_len: usize,
        xs: &[Vec<u8>],
        scale: f32,
    ) -> anyhow::Result<Vec<Vec<i16>>> {
        self.load_tile(w_tile, in_len, out_len)?;
        xs.iter()
            .map(|x| self.integrate_loaded(in_len, out_len, x, scale))
            .collect()
    }

    fn passes(&self) -> usize {
        self.passes
    }

    fn weight_loads(&self) -> usize {
        self.weight_loads
    }
}

/// One linear layer of an arbitrary-size model.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Row-major `[in_dim][out_dim]` integer weights on the 6-bit grid.
    pub weights: Vec<f32>,
    pub scale: f32,
    /// Apply ReLU + >>RELU_SHIFT requantisation after this layer.
    pub relu_requant: bool,
}

/// Slice one chunk's weight tile out of a layer's row-major matrix.
fn slice_tile(layer: &LayerSpec, chunk: &super::partition::Chunk) -> Vec<f32> {
    let ol = chunk.out_len();
    let mut tile = vec![0.0f32; chunk.in_len() * ol];
    for (ri, r) in (chunk.in_start..chunk.in_end).enumerate() {
        for (ci, col) in (chunk.out_start..chunk.out_end).enumerate() {
            tile[ri * ol + ci] = layer.weights[r * layer.out_dim + col];
        }
    }
    tile
}

/// The digital inter-layer requantisation (SIMD-CPU semantics).
fn requantise(layer: &LayerSpec, raw: &[i32]) -> Vec<u8> {
    if layer.relu_requant {
        raw.iter()
            .map(|&v| ((v.max(0) >> c::RELU_SHIFT).min(c::X_MAX)) as u8)
            .collect()
    } else {
        raw.iter().map(|&v| v.clamp(0, c::X_MAX) as u8).collect()
    }
}

/// Execute one layer's plan: chunks -> tiles -> digital partial sums.
/// Partial sums accumulate in i32 (the SIMD CPUs' width) **before** any
/// nonlinearity, exactly like fc1's split blocks in the paper's Fig 6.
pub fn run_layer<R: PassRunner>(
    runner: &mut R,
    layer: &LayerSpec,
    plan: &Plan,
    x: &[u8],
) -> anyhow::Result<Vec<i32>> {
    anyhow::ensure!(x.len() == layer.in_dim, "input dim");
    anyhow::ensure!(
        plan.in_dim == layer.in_dim && plan.out_dim == layer.out_dim,
        "plan/layer mismatch"
    );
    let mut out = vec![0i32; layer.out_dim];
    for chunk in &plan.chunks {
        let tile = slice_tile(layer, chunk);
        let adc = runner.run_tile(
            &tile,
            chunk.in_len(),
            chunk.out_len(),
            &x[chunk.in_start..chunk.in_end],
            layer.scale,
        )?;
        for (ci, &v) in adc.iter().enumerate() {
            out[chunk.out_start + ci] += v as i32; // digital partial sum
        }
    }
    Ok(out)
}

/// Batched layer execution: every chunk's weight tile is sliced and
/// written **once** and integrated against all `xs.len()` activation
/// vectors (`run_layer` re-sliced and re-wrote it per sample).  Per-sample
/// results are bit-identical to `run_layer`.
pub fn run_layer_batch<R: PassRunner>(
    runner: &mut R,
    layer: &LayerSpec,
    plan: &Plan,
    xs: &[Vec<u8>],
) -> anyhow::Result<Vec<Vec<i32>>> {
    anyhow::ensure!(!xs.is_empty(), "empty batch");
    anyhow::ensure!(
        plan.in_dim == layer.in_dim && plan.out_dim == layer.out_dim,
        "plan/layer mismatch"
    );
    for x in xs {
        anyhow::ensure!(x.len() == layer.in_dim, "input dim");
    }
    let mut out = vec![vec![0i32; layer.out_dim]; xs.len()];
    for chunk in &plan.chunks {
        let tile = slice_tile(layer, chunk);
        let slices: Vec<Vec<u8>> = xs
            .iter()
            .map(|x| x[chunk.in_start..chunk.in_end].to_vec())
            .collect();
        let adcs = runner.run_tile_batch(
            &tile,
            chunk.in_len(),
            chunk.out_len(),
            &slices,
            layer.scale,
        )?;
        anyhow::ensure!(adcs.len() == xs.len(), "runner batch shape");
        for (sample, adc) in out.iter_mut().zip(&adcs) {
            for (ci, &v) in adc.iter().enumerate() {
                sample[chunk.out_start + ci] += v as i32;
            }
        }
    }
    Ok(out)
}

/// Per-layer execution plans of a model, partitioned **once** and reused
/// across samples and batches (`run_model` used to re-partition every
/// layer on every call — once per sample under serving load).
#[derive(Debug, Clone)]
pub struct ModelPlan {
    plans: Vec<Plan>,
}

impl ModelPlan {
    pub fn of(layers: &[LayerSpec]) -> anyhow::Result<ModelPlan> {
        anyhow::ensure!(!layers.is_empty(), "empty model");
        let plans: Vec<Plan> = layers
            .iter()
            .map(|l| partition(l.in_dim, l.out_dim, c::N_HALVES))
            .collect();
        for plan in &plans {
            plan.check_invariants().map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(ModelPlan { plans })
    }

    pub fn plans(&self) -> &[Plan] {
        &self.plans
    }

    /// Integration cycles per sample.
    pub fn passes_per_sample(&self) -> usize {
        self.plans.iter().map(|p| p.passes()).sum()
    }
}

/// Execute a stack of layers end to end (5-bit activations between layers).
pub fn run_model<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    input: &[u8],
) -> anyhow::Result<Vec<i32>> {
    let plan = ModelPlan::of(layers)?;
    run_model_planned(runner, layers, &plan, input)
}

/// `run_model` against a pre-computed [`ModelPlan`].
pub fn run_model_planned<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    plan: &ModelPlan,
    input: &[u8],
) -> anyhow::Result<Vec<i32>> {
    anyhow::ensure!(layers.len() == plan.plans.len(), "plan/model mismatch");
    let mut acts: Vec<u8> = input.to_vec();
    let mut last_raw: Vec<i32> = acts.iter().map(|&a| a as i32).collect();
    for (layer, lplan) in layers.iter().zip(&plan.plans) {
        let raw = run_layer(runner, layer, lplan, &acts)?;
        acts = requantise(layer, &raw);
        last_raw = raw;
    }
    Ok(last_raw)
}

/// Batched model execution: for every layer, each weight tile is written
/// once per *batch* instead of once per sample.  Guarantee (property
/// tested): `run_model_batch(..)[i]` is bit-identical to
/// `run_model(.., inputs[i])` for every `i`.
pub fn run_model_batch<R: PassRunner>(
    runner: &mut R,
    layers: &[LayerSpec],
    plan: &ModelPlan,
    inputs: &[Vec<u8>],
) -> anyhow::Result<Vec<Vec<i32>>> {
    anyhow::ensure!(!inputs.is_empty(), "empty batch");
    anyhow::ensure!(layers.len() == plan.plans.len(), "plan/model mismatch");
    let mut acts: Vec<Vec<u8>> = inputs.to_vec();
    let mut last_raw: Vec<Vec<i32>> = acts
        .iter()
        .map(|a| a.iter().map(|&v| v as i32).collect())
        .collect();
    for (layer, lplan) in layers.iter().zip(&plan.plans) {
        let raw = run_layer_batch(runner, layer, lplan, &acts)?;
        acts = raw.iter().map(|r| requantise(layer, r)).collect();
        last_raw = raw;
    }
    Ok(last_raw)
}

/// Cost model: integration cycles + simulated chip time for a layer stack
/// (paper §III-A: oversize networks pay reconfiguration/serialisation).
pub fn cost_of(layers: &[(usize, usize)]) -> (usize, f64) {
    let passes: usize = layers
        .iter()
        .map(|&(i, o)| partition(i, o, c::N_HALVES).passes())
        .sum();
    let time_us = passes as f64 * c::INTEGRATION_CYCLE_US;
    (passes, time_us)
}

/// Chip-time cost of classifying a batch of `batch` samples.
#[derive(Debug, Clone, Copy)]
pub struct BatchCost {
    pub batch: usize,
    /// Integration cycles over the whole batch.
    pub passes: usize,
    /// Weight reconfigurations over the whole batch (once per tile).
    pub weight_loads: usize,
    /// Total simulated chip time for the batch [µs].
    pub total_us: f64,
}

impl BatchCost {
    pub fn per_sample_us(&self) -> f64 {
        self.total_us / self.batch as f64
    }
}

/// Batched cost model: integration work scales with the batch, but each
/// tile's weight write is paid once per batch — so per-sample cost
/// decreases monotonically in `batch` toward the pure-integration floor.
pub fn cost_of_batch(layers: &[(usize, usize)], batch: usize) -> BatchCost {
    assert!(batch > 0, "batch must be positive");
    let tiles: usize = layers
        .iter()
        .map(|&(i, o)| partition(i, o, c::N_HALVES).passes())
        .sum();
    let passes = tiles * batch;
    let total_us = tiles as f64 * c::WEIGHT_WRITE_US
        + passes as f64 * c::INTEGRATION_CYCLE_US;
    BatchCost { batch, passes, weight_loads: tiles, total_us }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::propcheck;
    use crate::util::rng::SplitMix64;

    fn rand_layer(
        rng: &mut SplitMix64,
        in_dim: usize,
        out_dim: usize,
        relu: bool,
    ) -> LayerSpec {
        LayerSpec {
            in_dim,
            out_dim,
            weights: (0..in_dim * out_dim)
                .map(|_| (rng.below(2 * c::W_MAX as u64 + 1) as i32
                    - c::W_MAX) as f32)
                .collect(),
            scale: 0.002,
            relu_requant: relu,
        }
    }

    /// Float reference for a single layer in the linear regime.
    fn dense_ref(layer: &LayerSpec, x: &[u8]) -> Vec<f64> {
        let mut out = vec![0.0f64; layer.out_dim];
        for (r, &xv) in x.iter().enumerate() {
            for col in 0..layer.out_dim {
                out[col] += xv as f64
                    * layer.weights[r * layer.out_dim + col] as f64;
            }
        }
        out
    }

    #[test]
    fn single_chip_layer_matches_reference() {
        let mut rng = SplitMix64::new(1);
        let layer = rand_layer(&mut rng, 200, 100, false);
        let x: Vec<u8> = (0..200).map(|_| rng.below(4) as u8).collect();
        let plan = partition(200, 100, 2);
        let mut runner = NativeRunner::new();
        let got = run_layer(&mut runner, &layer, &plan, &x).unwrap();
        let want = dense_ref(&layer, &x);
        for (g, w) in got.iter().zip(&want) {
            let expect = (w * layer.scale as f64).round().clamp(-128.0, 127.0);
            assert!(
                (*g as f64 - expect).abs() <= 1.0,
                "got {g} want {expect}"
            );
        }
        assert_eq!(runner.passes(), 1);
    }

    #[test]
    fn oversize_layer_partial_sums() {
        // 600 inputs -> 3 input tiles; digital accumulation must match the
        // direct dense product in the linear regime.
        let mut rng = SplitMix64::new(2);
        let layer = rand_layer(&mut rng, 600, 300, false);
        // Small activations keep each *partial* sum inside the ADC range.
        let x: Vec<u8> = (0..600).map(|_| rng.below(2) as u8).collect();
        let plan = partition(600, 300, 2);
        let mut runner = NativeRunner::new();
        let got = run_layer(&mut runner, &layer, &plan, &x).unwrap();
        assert_eq!(runner.passes(), plan.passes());
        let want = dense_ref(&layer, &x);
        let mut worst = 0.0f64;
        for (g, w) in got.iter().zip(&want) {
            let expect = w * layer.scale as f64;
            worst = worst.max((*g as f64 - expect).abs());
        }
        // Each tile rounds independently: error <= 0.5 LSB per input tile.
        assert!(worst <= 3.0 * 0.5 + 1e-9, "worst {worst}");
    }

    #[test]
    fn multi_layer_stack_runs() {
        let mut rng = SplitMix64::new(3);
        let layers = vec![
            rand_layer(&mut rng, 300, 400, true),
            rand_layer(&mut rng, 400, 150, true),
            rand_layer(&mut rng, 150, 10, false),
        ];
        let x: Vec<u8> = (0..300).map(|_| rng.below(8) as u8).collect();
        let mut runner = NativeRunner::new();
        let out = run_model(&mut runner, &layers, &x).unwrap();
        assert_eq!(out.len(), 10);
        // 300x400: 2x2=4 chunks; 400x150: 2 chunks; 150x10: 1 chunk.
        assert_eq!(runner.passes(), 4 + 2 + 1);
    }

    #[test]
    fn executor_equivalence_property() {
        propcheck::check("executor_matches_dense", 12, 0xFACE, |g| {
            let in_dim = g.usize_in(1, 520);
            let out_dim = g.usize_in(1, 300);
            let mut rng = SplitMix64::new(g.rng.next_u64());
            let layer = rand_layer(&mut rng, in_dim, out_dim, false);
            let x: Vec<u8> =
                (0..in_dim).map(|_| rng.below(2) as u8).collect();
            let plan = partition(in_dim, out_dim, 2);
            let mut runner = NativeRunner::new();
            let got = run_layer(&mut runner, &layer, &plan, &x)
                .map_err(|e| e.to_string())?;
            let want = dense_ref(&layer, &x);
            let tiles = in_dim.div_ceil(c::K_LOGICAL) as f64;
            for (i, (gv, wv)) in got.iter().zip(&want).enumerate() {
                let expect = wv * layer.scale as f64;
                // Only check columns whose exact value stays linear.
                if expect.abs() < 100.0 {
                    prop_assert!(
                        (*gv as f64 - expect).abs() <= 0.5 * tiles + 1e-6,
                        "col {i}: got {gv} want {expect}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn profile_correction_recovers_nominal_layer() {
        use crate::asic::array::ColumnCalib;
        use crate::calib::ColumnCorrection;

        let mut rng = SplitMix64::new(31);
        let layer = rand_layer(&mut rng, 200, 120, false);
        let x: Vec<u8> = (0..200).map(|_| rng.below(3) as u8).collect();
        let plan = partition(200, 120, 2);
        let mut nominal = NativeRunner::new();
        let want = run_layer(&mut nominal, &layer, &plan, &x).unwrap();

        let mut fpn_rng = SplitMix64::new(77);
        let calib = ColumnCalib::fixed_pattern(c::N_COLS, &mut fpn_rng);
        // Uncompensated fixed pattern: raw deviation from the ideal.
        let mut raw = NativeRunner::with_calib(calib.clone());
        let got_raw = run_layer(&mut raw, &layer, &plan, &x).unwrap();
        // Measure the pattern (noise-free) and run compensated.
        let mut comp = NativeRunner::with_calib(calib);
        let m = crate::asic::calib::calibrate_half_with(
            comp.array_mut(),
            &mut SplitMix64::new(5),
            16,
            0.0,
        );
        comp.set_correction(Some(ColumnCorrection::from_measured(
            &m.gain_est,
            &m.offset_est,
        )));
        let got = run_layer(&mut comp, &layer, &plan, &x).unwrap();

        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 3,
                "col {i}: compensated {g} vs nominal {w}"
            );
        }
        let dev = |a: &[i32]| -> i64 {
            a.iter().zip(&want).map(|(v, w)| (v - w).abs() as i64).sum()
        };
        assert!(
            dev(&got) <= dev(&got_raw),
            "compensation must not be worse than the raw fixed pattern \
             ({} vs {})",
            dev(&got),
            dev(&got_raw)
        );
    }

    #[test]
    fn cost_model_scales() {
        let (p_small, t_small) = cost_of(&[(256, 256)]);
        assert_eq!(p_small, 1);
        assert!((t_small - c::INTEGRATION_CYCLE_US).abs() < 1e-9);
        let (p_big, _) = cost_of(&[(1024, 1024)]);
        assert_eq!(p_big, 16);
        // Paper §V scale: a 10M-parameter model is time-multiplexable.
        let (p_huge, t_huge) = cost_of(&[(3000, 3000), (3000, 1000)]);
        assert!(p_huge > 100);
        assert!(t_huge > 500.0);
    }

    #[test]
    fn batch_cost_amortises_weight_writes() {
        let shapes = [(600usize, 300usize), (300, 10)];
        let c1 = cost_of_batch(&shapes, 1);
        // 600x300: 3x2 = 6 tiles; 300x10: 2 tiles.
        assert_eq!(c1.weight_loads, 8);
        assert_eq!(c1.passes, 8);
        let mut prev = c1.per_sample_us();
        for b in [2usize, 4, 8, 16, 32] {
            let cb = cost_of_batch(&shapes, b);
            assert_eq!(cb.weight_loads, 8, "loads are per batch, not sample");
            assert_eq!(cb.passes, 8 * b, "integrations are per sample");
            let per = cb.per_sample_us();
            assert!(per < prev, "B={b}: {per} !< {prev}");
            prev = per;
        }
        // The floor is the pure-integration cost.
        let floor = 8.0 * c::INTEGRATION_CYCLE_US;
        assert!(prev > floor);
        assert!(prev - floor < 8.0 * c::WEIGHT_WRITE_US / 32.0 + 1e-9);
    }

    #[test]
    fn native_runner_batch_loads_weights_once() {
        let mut rng = SplitMix64::new(11);
        let layer = rand_layer(&mut rng, 600, 300, false);
        let plan = partition(600, 300, 2);
        let xs: Vec<Vec<u8>> = (0..4)
            .map(|_| (0..600).map(|_| rng.below(2) as u8).collect())
            .collect();
        let mut runner = NativeRunner::new();
        let out = run_layer_batch(&mut runner, &layer, &plan, &xs).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(runner.passes(), 4 * plan.passes());
        assert_eq!(runner.weight_loads(), plan.passes(), "one write per tile");
    }

    /// Acceptance property: `run_model_batch(B)[i] == run_model(sample_i)`
    /// bit-for-bit, for random layer stacks and batch sizes.
    #[test]
    fn model_batch_matches_sequential_property() {
        propcheck::check("run_model_batch_parity", 10, 0xBA7C4, |g| {
            let mut rng = SplitMix64::new(g.rng.next_u64());
            let d0 = g.usize_in(1, 400);
            let d1 = g.usize_in(1, 300);
            let d2 = g.usize_in(1, 64);
            let layers = vec![
                rand_layer(&mut rng, d0, d1, true),
                rand_layer(&mut rng, d1, d2, false),
            ];
            let batch = g.usize_in(1, 6);
            let inputs: Vec<Vec<u8>> = (0..batch)
                .map(|_| (0..d0).map(|_| rng.below(8) as u8).collect())
                .collect();
            let plan = ModelPlan::of(&layers).map_err(|e| e.to_string())?;
            let mut batch_runner = NativeRunner::new();
            let got =
                run_model_batch(&mut batch_runner, &layers, &plan, &inputs)
                    .map_err(|e| e.to_string())?;
            for (i, input) in inputs.iter().enumerate() {
                let mut seq_runner = NativeRunner::new();
                let want = run_model(&mut seq_runner, &layers, input)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    got[i] == want,
                    "sample {i}: batch {:?} != sequential {:?}",
                    &got[i][..want.len().min(8)],
                    &want[..want.len().min(8)]
                );
            }
            // Amortisation: the batch path writes each tile once.
            prop_assert!(
                batch_runner.weight_loads() == plan.passes_per_sample(),
                "weight loads {} != tiles {}",
                batch_runner.weight_loads(),
                plan.passes_per_sample()
            );
            Ok(())
        });
    }
}
