//! Power measurement pipeline: INA219 sensor models + block averaging
//! (paper §II-B, §IV).
//!
//! "The individual supply currents of the BrainScaleS ASIC can be monitored
//! by several shunt-based power monitoring ICs."  Measurements in §IV were
//! taken "with a sampling rate of 294 Hz for sensors on the system
//! controller and 4.4 kHz for sensors on the ASIC adapter PCB", then
//! averaged over 500-trace blocks down to a single inference.
//!
//! The INA219 model reproduces the datasheet quantisation: bus-voltage LSB
//! 4 mV, shunt-voltage LSB 10 µV across a configurable shunt resistor, and
//! sampled integration of a (piecewise-constant) power trace.

use super::energy::Component;

/// One shunt-based power monitor on a rail.
#[derive(Debug, Clone)]
pub struct Ina219 {
    pub component: Component,
    pub rail_v: f64,
    pub shunt_ohm: f64,
    pub sample_hz: f64,
    /// Accumulated samples [W].
    pub samples: Vec<f64>,
}

impl Ina219 {
    /// ASIC-adapter sensors: 4.4 kHz; controller sensors: 294 Hz (paper §IV).
    pub fn for_component(component: Component) -> Ina219 {
        let on_adapter = matches!(
            component,
            Component::AsicIo | Component::AsicAnalog | Component::AsicDigital
        );
        Ina219 {
            component,
            rail_v: if on_adapter { 1.2 } else { 5.0 },
            shunt_ohm: if on_adapter { 0.1 } else { 0.02 },
            sample_hz: if on_adapter { 4400.0 } else { 294.0 },
            samples: Vec::new(),
        }
    }

    /// Datasheet quantisation of one instantaneous power value.
    pub fn quantize(&self, power_w: f64) -> f64 {
        let current_a = power_w / self.rail_v;
        let shunt_v = current_a * self.shunt_ohm;
        let shunt_lsb = 10e-6; // 10 µV
        let q_shunt = (shunt_v / shunt_lsb).round() * shunt_lsb;
        let bus_lsb = 4e-3; // 4 mV
        let q_bus = (self.rail_v / bus_lsb).round() * bus_lsb;
        (q_shunt / self.shunt_ohm) * q_bus
    }

    /// Sample a constant power level held for `dur_s`.
    pub fn sample_constant(&mut self, power_w: f64, dur_s: f64) {
        let n = (dur_s * self.sample_hz).floor() as usize;
        let q = self.quantize(power_w);
        self.samples.extend(std::iter::repeat(q).take(n.max(1)));
    }

    pub fn mean_w(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

/// The block-measurement procedure of §IV: hold each component's mean power
/// over the block duration, sample with the respective sensor, average down
/// to per-inference figures.
pub struct BlockMeasurement {
    pub sensors: Vec<Ina219>,
    pub block_len: usize,
    pub block_duration_s: f64,
}

impl BlockMeasurement {
    pub fn new(block_len: usize) -> BlockMeasurement {
        BlockMeasurement {
            sensors: super::energy::ALL_COMPONENTS
                .iter()
                .map(|&c| Ina219::for_component(c))
                .collect(),
            block_len,
            block_duration_s: 0.0,
        }
    }

    /// Record a processed block given its per-component energy totals [J]
    /// and the block duration.
    pub fn record_block(&mut self, component_j: &[(Component, f64)], dur_s: f64) {
        self.block_duration_s += dur_s;
        for sensor in &mut self.sensors {
            let j = component_j
                .iter()
                .find(|(c, _)| *c == sensor.component)
                .map(|(_, j)| *j)
                .unwrap_or(0.0);
            sensor.sample_constant(j / dur_s, dur_s);
        }
    }

    /// Sensor lookup; `None` when no monitor was configured for the rail.
    pub fn sensor(&self, component: Component) -> Option<&Ina219> {
        self.sensors.iter().find(|s| s.component == component)
    }

    /// Mutable accessor that registers a sensor for the rail on first use,
    /// so a misconfigured sensor set cannot crash the power pipeline.
    pub fn sensor_mut(&mut self, component: Component) -> &mut Ina219 {
        if let Some(i) =
            self.sensors.iter().position(|s| s.component == component)
        {
            return &mut self.sensors[i];
        }
        self.sensors.push(Ina219::for_component(component));
        self.sensors.last_mut().unwrap()
    }

    /// Per-inference energy of one component as the sensors saw it [J].
    /// A rail without a configured sensor reads 0 J (nothing was sampled)
    /// instead of panicking the pipeline — loudly, so a misconfigured
    /// sensor set corrupting a Table-1 figure is visible in the logs.
    pub fn measured_j(&self, component: Component) -> f64 {
        match self.sensor(component) {
            Some(s) => {
                s.mean_w() * self.block_duration_s / self.block_len as f64
            }
            None => {
                log::warn!(
                    "power monitor: no sensor configured for the \
                     {component:?} rail — reporting 0 J"
                );
                0.0
            }
        }
    }

    pub fn measured_total_j(&self) -> f64 {
        super::energy::ALL_COMPONENTS
            .iter()
            .map(|&c| self.measured_j(c))
            .sum()
    }

    pub fn measured_system_w(&self) -> f64 {
        self.sensors.iter().map(|s| s.mean_w()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantisation_is_small_relative_error() {
        let s = Ina219::for_component(Component::AsicAnalog);
        for p in [0.05, 0.14, 0.69, 1.0] {
            let q = s.quantize(p);
            assert!((q - p).abs() / p < 0.01, "power {p} -> {q}");
        }
    }

    #[test]
    fn sampling_rates_follow_paper() {
        let a = Ina219::for_component(Component::AsicIo);
        assert_eq!(a.sample_hz, 4400.0);
        let c = Ina219::for_component(Component::ArmCores);
        assert_eq!(c.sample_hz, 294.0);
    }

    #[test]
    fn sample_counts_scale_with_duration() {
        let mut s = Ina219::for_component(Component::AsicAnalog);
        s.sample_constant(0.5, 1.0);
        assert_eq!(s.samples.len(), 4400);
        let mut c = Ina219::for_component(Component::Dram);
        c.sample_constant(0.5, 1.0);
        assert_eq!(c.samples.len(), 294);
    }

    #[test]
    fn block_measurement_recovers_energy() {
        let mut bm = BlockMeasurement::new(500);
        // 500 inferences of 276 µs at 0.69 W on the ASIC-analog rail.
        let dur = 500.0 * 276e-6;
        let je = 0.69 * dur;
        bm.record_block(&[(Component::AsicAnalog, je)], dur);
        let per_inf = bm.measured_j(Component::AsicAnalog);
        let want = je / 500.0;
        assert!(
            (per_inf - want).abs() / want < 0.02,
            "measured {per_inf} want {want}"
        );
    }

    #[test]
    fn missing_sensor_reads_zero_instead_of_panicking() {
        let mut bm = BlockMeasurement::new(500);
        // A misconfigured rail: the ASIC-analog sensor was never fitted.
        bm.sensors.retain(|s| s.component != Component::AsicAnalog);
        bm.record_block(&[(Component::AsicAnalog, 1.0)], 0.1);
        assert_eq!(bm.measured_j(Component::AsicAnalog), 0.0);
        // The total still sums the rails that do have sensors.
        let _ = bm.measured_total_j();
        // The mutable accessor registers the sensor on first use.
        let s = bm.sensor_mut(Component::AsicAnalog);
        assert_eq!(s.component, Component::AsicAnalog);
        assert!(bm.sensor(Component::AsicAnalog).is_some());
        // Registering is idempotent: no duplicate sensors.
        let n = bm.sensors.len();
        let _ = bm.sensor_mut(Component::AsicAnalog);
        assert_eq!(bm.sensors.len(), n);
    }

    #[test]
    fn short_blocks_still_produce_a_sample() {
        let mut s = Ina219::for_component(Component::ArmCores);
        s.sample_constant(1.0, 1e-4); // << sample period
        assert_eq!(s.samples.len(), 1);
    }
}
