//! Activity-based power/energy model of the BSS-2 mobile system.
//!
//! Calibration targets are the paper's Table 1 measurements at 276 µs per
//! inference (500-trace block):
//!
//! | component                     | energy/inf | implied mean power |
//! |-------------------------------|-----------|--------------------|
//! | system total                  | 1.56 mJ   | 5.6 W              |
//! | system controller (ARM cores) | 0.34 mJ   | 1.23 W             |
//! | system controller (FPGA)      | 0.21 mJ   | 0.76 W             |
//! | system controller (DRAM)      | 0.12 mJ   | 0.43 W             |
//! | ASIC total                    | 0.19 mJ   | 0.69 W             |
//! |   ASIC IO / analog / digital  | 0.07 / 0.07 / 0.07 mJ           |
//! | remainder (regulators, board) | ~0.67 mJ  | ~2.4 W             |
//!
//! Each component is modelled as static power plus activity-proportional
//! dynamic energy; the constants below are fitted so a standard inference
//! (3 array passes, ~300 events, one 4 KiB DMA window, SIMD post-processing)
//! reproduces the table, while remaining *mechanistic*: fewer events or
//! passes reduce the respective component, which the ablation benches probe.

use crate::asic::chip::ChipStats;
use crate::fpga::dma::DmaStats;

/// Power rails of the mobile system (paper §II-B: six supply rails on the
/// adapter + the controller rails; we group them by Table 1 components).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Component {
    ArmCores,
    FpgaFabric,
    Dram,
    AsicIo,
    AsicAnalog,
    AsicDigital,
    Board, // regulators, clocking, misc board overhead
}

pub const ALL_COMPONENTS: [Component; 7] = [
    Component::ArmCores,
    Component::FpgaFabric,
    Component::Dram,
    Component::AsicIo,
    Component::AsicAnalog,
    Component::AsicDigital,
    Component::Board,
];

impl Component {
    pub fn name(self) -> &'static str {
        match self {
            Component::ArmCores => "system controller, ARM CPU",
            Component::FpgaFabric => "system controller, FPGA",
            Component::Dram => "system controller, DRAM",
            Component::AsicIo => "ASIC, IO",
            Component::AsicAnalog => "ASIC, analog",
            Component::AsicDigital => "ASIC, digital",
            Component::Board => "board overhead (regulators)",
        }
    }

    /// Static (idle) power draw [W] while the system is powered.
    pub fn static_w(self) -> f64 {
        match self {
            // The ARM cores "do not participate in the inner loop" — their
            // draw is mostly OS idle + sensor service, nearly constant.
            Component::ArmCores => 1.20,
            Component::FpgaFabric => 0.55,
            Component::Dram => 0.25,
            Component::AsicIo => 0.25,   // always-on serial links
            Component::AsicAnalog => 0.14, // bias currents, PLL share
            Component::AsicDigital => 0.15,
            Component::Board => 2.60,
        }
    }
}

/// Dynamic energy coefficients (fitted, see module docs).
pub mod dynamic {
    /// Energy per event crossing the serial links [J].
    pub const PER_EVENT_IO_J: f64 = 80e-12;
    /// Analog energy per integration cycle (synapse drivers + neurons
    /// + membrane reset of one half) [J].
    pub const PER_VMM_ANALOG_J: f64 = 9.5e-6;
    /// Digital energy per integration cycle (event router, sequencer) [J].
    pub const PER_VMM_DIGITAL_J: f64 = 8.0e-6;
    /// Energy per parallel ADC read of one half [J].
    pub const PER_ADC_READ_ANALOG_J: f64 = 2.0e-6;
    /// SIMD CPU energy per cycle [J] (245 MHz embedded core).
    pub const PER_SIMD_CYCLE_J: f64 = 60e-12;
    /// FPGA fabric energy per preprocessed sample [J].
    pub const PER_PP_SAMPLE_J: f64 = 9.0e-9;
    /// DRAM energy per byte moved [J].
    pub const PER_DRAM_BYTE_J: f64 = 5e-9;
    /// FPGA energy per event generated/traced [J].
    pub const PER_EVENT_FPGA_J: f64 = 150e-12;
}

/// Activity record of one inference (filled by the engine).
#[derive(Debug, Default, Clone)]
pub struct Activity {
    pub chip: ChipStats,
    pub dma: DmaStats,
    pub preprocessed_samples: u64,
    pub events_generated: u64,
    /// Simulated wall-clock of the inference [s].
    pub duration_s: f64,
}

/// Energy breakdown of one inference [J per component].
#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    pub per_component: Vec<(Component, f64)>,
    pub duration_s: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.per_component.iter().map(|(_, j)| j).sum()
    }

    pub fn component_j(&self, c: Component) -> f64 {
        self.per_component
            .iter()
            .find(|(k, _)| *k == c)
            .map(|(_, j)| *j)
            .unwrap_or(0.0)
    }

    pub fn asic_j(&self) -> f64 {
        self.component_j(Component::AsicIo)
            + self.component_j(Component::AsicAnalog)
            + self.component_j(Component::AsicDigital)
    }

    pub fn controller_j(&self) -> f64 {
        self.component_j(Component::ArmCores)
            + self.component_j(Component::FpgaFabric)
            + self.component_j(Component::Dram)
    }

    pub fn mean_power_w(&self) -> f64 {
        self.total_j() / self.duration_s
    }

    /// Uniform share of a batch-level breakdown (e.g. `1/B` per sample).
    /// `scaled(1.0)` is exactly `self`.
    pub fn scaled(&self, factor: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            per_component: self
                .per_component
                .iter()
                .map(|&(comp, j)| (comp, j * factor))
                .collect(),
            duration_s: self.duration_s * factor,
        }
    }

    pub fn asic_power_w(&self) -> f64 {
        self.asic_j() / self.duration_s
    }
}

/// Evaluate the model for one inference's activity.
pub fn energy_of(activity: &Activity) -> EnergyBreakdown {
    use dynamic::*;
    let t = activity.duration_s;
    let ch = &activity.chip;

    let mut out = Vec::with_capacity(ALL_COMPONENTS.len());
    for comp in ALL_COMPONENTS {
        let static_j = comp.static_w() * t;
        let dyn_j = match comp {
            Component::ArmCores => 0.0, // not in the inner loop (paper §II-C)
            Component::FpgaFabric => {
                activity.preprocessed_samples as f64 * PER_PP_SAMPLE_J
                    + activity.events_generated as f64 * PER_EVENT_FPGA_J
            }
            Component::Dram => {
                (activity.dma.bytes as f64) * PER_DRAM_BYTE_J
            }
            Component::AsicIo => ch.events_sent as f64 * PER_EVENT_IO_J,
            Component::AsicAnalog => {
                ch.vmm_cycles as f64 * PER_VMM_ANALOG_J
                    + ch.adc_reads as f64 * PER_ADC_READ_ANALOG_J
            }
            Component::AsicDigital => {
                ch.vmm_cycles as f64 * PER_VMM_DIGITAL_J
                    + ch.simd_cycles as f64 * PER_SIMD_CYCLE_J
            }
            Component::Board => 0.0, // pure static (regulator efficiency)
        };
        out.push((comp, static_j + dyn_j));
    }
    EnergyBreakdown { per_component: out, duration_s: t }
}

/// CR2032 battery-life estimate (paper §V): energy content ~200 mAh at 3 V.
pub fn cr2032_years(energy_per_classification_j: f64, interval_s: f64) -> f64 {
    let battery_j = 0.200 * 3.0 * 3600.0; // 2160 J
    let per_day = 86_400.0 / interval_s;
    let days = battery_j / (energy_per_classification_j * per_day);
    days / 365.25
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Activity profile of one standard ECG inference (3 passes, the
    /// engine's typical event counts).
    pub fn standard_inference() -> Activity {
        use crate::asic::consts as c;
        Activity {
            chip: ChipStats {
                events_sent: 300,
                vmm_cycles: 3,
                adc_reads: 3,
                simd_cycles: 300,
                weight_writes: 2,
            },
            dma: DmaStats {
                transfers: 2,
                bytes: (c::ECG_WINDOW * c::ECG_CHANNELS * 2) as u64,
                time_ns: 1000.0,
                drops: 0,
            },
            preprocessed_samples: (c::ECG_WINDOW * c::ECG_CHANNELS) as u64,
            events_generated: 300,
            duration_s: 276e-6,
        }
    }

    #[test]
    fn table1_system_power() {
        let e = energy_of(&standard_inference());
        let p = e.mean_power_w();
        assert!((p - 5.6).abs() < 0.3, "system power {p} W (paper 5.6)");
    }

    #[test]
    fn table1_total_energy() {
        let e = energy_of(&standard_inference());
        let mj = e.total_j() * 1e3;
        assert!((mj - 1.56).abs() < 0.1, "total {mj} mJ (paper 1.56)");
    }

    #[test]
    fn table1_asic_breakdown() {
        let e = energy_of(&standard_inference());
        let asic_mj = e.asic_j() * 1e3;
        assert!((asic_mj - 0.19).abs() < 0.04, "asic {asic_mj} mJ (paper 0.19)");
        for comp in [Component::AsicIo, Component::AsicAnalog, Component::AsicDigital] {
            let mj = e.component_j(comp) * 1e3;
            assert!((mj - 0.07).abs() < 0.025, "{:?} {mj} mJ (paper 0.07)", comp);
        }
        let p = e.asic_power_w();
        assert!((p - 0.69).abs() < 0.12, "asic power {p} W (paper 0.69)");
    }

    #[test]
    fn table1_controller_breakdown() {
        let e = energy_of(&standard_inference());
        let arm = e.component_j(Component::ArmCores) * 1e3;
        let fpga = e.component_j(Component::FpgaFabric) * 1e3;
        let dram = e.component_j(Component::Dram) * 1e3;
        assert!((arm - 0.34).abs() < 0.04, "arm {arm} (paper 0.34)");
        assert!((fpga - 0.21).abs() < 0.04, "fpga {fpga} (paper 0.21)");
        assert!((dram - 0.12).abs() < 0.04, "dram {dram} (paper 0.12)");
        let ctrl = e.controller_j() * 1e3;
        assert!((ctrl - 0.7).abs() < 0.1, "controller {ctrl} (paper 0.7)");
    }

    #[test]
    fn energy_scales_with_activity() {
        let base = energy_of(&standard_inference());
        let mut busy = standard_inference();
        busy.chip.vmm_cycles *= 4;
        busy.chip.events_sent *= 4;
        let e = energy_of(&busy);
        assert!(e.asic_j() > base.asic_j() * 1.5);
        // ARM energy is unchanged (static only).
        assert_eq!(
            e.component_j(Component::ArmCores),
            base.component_j(Component::ArmCores)
        );
    }

    #[test]
    fn cr2032_five_years_at_two_minutes() {
        // Paper §V: a CR2032 powers the *inference calculations* (the ASIC
        // energy, 0.19 mJ averaged over batch-500 blocks... the paper quotes
        // the full per-classification energy against the battery at 2-min
        // intervals giving ~5 years).  With 1.56 mJ per classification every
        // 120 s: 2160 J / (1.56e-3 * 720/day) ≈ 5.3 years.
        let years = cr2032_years(1.56e-3, 120.0);
        assert!((years - 5.0).abs() < 0.5, "battery life {years} years");
    }
}
