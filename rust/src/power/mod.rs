//! Power/energy subsystem: the measurement pipeline behind paper Table 1.
//!
//! * [`energy`] — activity-based per-component energy model + CR2032
//!   battery estimate (paper §V).
//! * [`monitor`] — INA219 sensor models and the §IV block-averaging
//!   measurement procedure (294 Hz / 4.4 kHz sampling).

pub mod energy;
pub mod monitor;
