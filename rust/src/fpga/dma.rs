//! DMA controller + DRAM model (paper §II-C, Fig 5).
//!
//! "A DMA controller reads the input data from memory, converts it into
//! input events, and sends them to the ASIC. [...] this DMA controller is
//! programmed by the SIMD CPU on the ASIC to transfer the raw signal data,
//! an ECG trace composed of 12-bit values, from memory."
//!
//! The model couples a word-addressed DRAM (LPDDR4 bandwidth/latency
//! parameters) with descriptor-based transfers feeding the preprocessing
//! chain, and accounts bytes moved for the DRAM energy estimate.

use super::preprocess::StreamingPreprocessor;

/// LPDDR4-2133 x32: ~8.5 GB/s peak, ~100 ns random-access latency.
pub const DRAM_BYTES_PER_NS: f64 = 8.5;
pub const DRAM_LATENCY_NS: f64 = 100.0;

/// Word-addressed DRAM with access statistics.
#[derive(Debug, Default)]
pub struct Dram {
    // Ordered map so DRAM contents replay deterministically (lint:
    // det-unordered-map).
    mem: std::collections::BTreeMap<u32, u32>,
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl Dram {
    pub fn write_words(&mut self, addr: u32, data: &[u32]) {
        for (i, &w) in data.iter().enumerate() {
            self.mem.insert(addr + i as u32 * 4, w);
        }
        self.writes += 1;
        self.bytes_written += data.len() as u64 * 4;
    }

    pub fn read_words(&mut self, addr: u32, len: usize) -> Vec<u32> {
        self.reads += 1;
        self.bytes_read += len as u64 * 4;
        (0..len)
            .map(|i| *self.mem.get(&(addr + i as u32 * 4)).unwrap_or(&0))
            .collect()
    }

    /// Pack 12-bit samples two-per-word (16-bit aligned, as the real
    /// controller stores u16 little-endian pairs).
    pub fn write_samples(&mut self, addr: u32, samples: &[u16]) {
        let words: Vec<u32> = samples
            .chunks(2)
            .map(|c| {
                let lo = c[0] as u32;
                let hi = c.get(1).copied().unwrap_or(0) as u32;
                lo | (hi << 16)
            })
            .collect();
        self.write_words(addr, &words);
    }

    pub fn read_samples(&mut self, addr: u32, n: usize) -> Vec<u16> {
        let words = self.read_words(addr, n.div_ceil(2));
        let mut out = Vec::with_capacity(n);
        for w in words {
            out.push((w & 0xFFFF) as u16);
            if out.len() < n {
                out.push((w >> 16) as u16);
            }
        }
        out.truncate(n);
        out
    }
}

/// One DMA descriptor: transfer `n_samples` 12-bit samples starting at
/// `src_addr` into the preprocessing chain.
#[derive(Debug, Clone, Copy)]
pub struct Descriptor {
    pub src_addr: u32,
    pub n_samples: usize,
}

/// DMA engine statistics (feeds timing + DRAM energy).
#[derive(Debug, Default, Clone, Copy)]
pub struct DmaStats {
    pub transfers: u64,
    pub bytes: u64,
    pub time_ns: f64,
    /// Descriptor transfers whose frame was lost (fault injection): the
    /// descriptor round trip was paid but no data reached the fabric.
    pub drops: u64,
}

pub struct DmaController {
    pub stats: DmaStats,
    /// Armed by the fault injector: the next descriptor loses its frame.
    drop_next: bool,
}

impl Default for DmaController {
    fn default() -> Self {
        Self::new()
    }
}

impl DmaController {
    pub fn new() -> DmaController {
        DmaController { stats: DmaStats::default(), drop_next: false }
    }

    /// Arm a frame drop: the next [`run`](DmaController::run) loses its
    /// frame (counted in [`DmaStats::drops`]), after which transfers are
    /// clean again.  The engine aborts the program when it sees a drop —
    /// a partial activation vector must never reach the chip silently.
    pub fn inject_drop(&mut self) {
        self.drop_next = true;
    }

    /// Execute a descriptor: stream samples from DRAM through the
    /// preprocessing chain (as the fabric does sample-per-clock).
    pub fn run(
        &mut self,
        dram: &mut Dram,
        desc: Descriptor,
        pp: &mut StreamingPreprocessor,
    ) {
        if self.drop_next {
            // Frame lost in flight: the descriptor round trip is paid,
            // nothing reaches the preprocessor, the drop is counted.
            self.drop_next = false;
            self.stats.transfers += 1;
            self.stats.drops += 1;
            self.stats.time_ns += DRAM_LATENCY_NS;
            return;
        }
        let samples = dram.read_samples(desc.src_addr, desc.n_samples);
        pp.push_channel(&samples);
        let bytes = desc.n_samples as u64 * 2;
        self.stats.transfers += 1;
        self.stats.bytes += bytes;
        self.stats.time_ns +=
            DRAM_LATENCY_NS + bytes as f64 / DRAM_BYTES_PER_NS;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::consts as c;

    #[test]
    fn dram_word_roundtrip() {
        let mut d = Dram::default();
        d.write_words(0x100, &[1, 2, 3]);
        assert_eq!(d.read_words(0x100, 3), vec![1, 2, 3]);
        assert_eq!(d.read_words(0x200, 2), vec![0, 0]);
    }

    #[test]
    fn sample_packing_roundtrip() {
        let mut d = Dram::default();
        let samples: Vec<u16> = (0..101).map(|i| (i * 37 % 4096) as u16).collect();
        d.write_samples(0x0, &samples);
        assert_eq!(d.read_samples(0x0, 101), samples);
    }

    #[test]
    fn dma_streams_through_preprocessor() {
        let mut dram = Dram::default();
        let mut raw = vec![2048u16; c::ECG_WINDOW];
        raw[40] = 3000;
        dram.write_samples(0x1000, &raw);
        let mut dma = DmaController::new();
        let mut pp = StreamingPreprocessor::new();
        dma.run(
            &mut dram,
            Descriptor { src_addr: 0x1000, n_samples: c::ECG_WINDOW },
            &mut pp,
        );
        assert_eq!(pp.out.len(), c::POOLED_LEN);
        assert!(pp.out[1] > 0, "spike bin must fire");
        assert_eq!(dma.stats.bytes, c::ECG_WINDOW as u64 * 2);
        assert!(dma.stats.time_ns > DRAM_LATENCY_NS);
    }

    #[test]
    fn dma_time_scales_with_size() {
        let mut dram = Dram::default();
        dram.write_samples(0, &vec![0u16; 4096]);
        let mut dma = DmaController::new();
        let mut pp = StreamingPreprocessor::new();
        dma.run(&mut dram, Descriptor { src_addr: 0, n_samples: 64 }, &mut pp);
        let t1 = dma.stats.time_ns;
        dma.run(&mut dram, Descriptor { src_addr: 0, n_samples: 4096 }, &mut pp);
        let t2 = dma.stats.time_ns - t1;
        assert!(t2 > t1);
    }

    #[test]
    fn injected_drop_loses_exactly_one_frame() {
        let mut dram = Dram::default();
        dram.write_samples(0x1000, &vec![2048u16; c::ECG_WINDOW]);
        let mut dma = DmaController::new();
        let mut pp = StreamingPreprocessor::new();
        let desc = Descriptor { src_addr: 0x1000, n_samples: c::ECG_WINDOW };
        dma.inject_drop();
        dma.run(&mut dram, desc, &mut pp);
        // The dropped frame never reached the fabric; no bytes counted.
        assert_eq!(pp.out.len(), 0);
        assert_eq!(dma.stats.drops, 1);
        assert_eq!(dma.stats.bytes, 0);
        assert!(dma.stats.time_ns > 0.0, "the round trip is still paid");
        // The very next transfer is clean again.
        dma.run(&mut dram, desc, &mut pp);
        assert_eq!(pp.out.len(), c::POOLED_LEN);
        assert_eq!(dma.stats.drops, 1);
        assert_eq!(dma.stats.bytes, c::ECG_WINDOW as u64 * 2);
    }

    #[test]
    fn dram_counts_bytes() {
        let mut d = Dram::default();
        d.write_samples(0, &[1, 2, 3, 4]);
        assert_eq!(d.bytes_written, 8);
        d.read_samples(0, 4);
        assert_eq!(d.bytes_read, 8);
    }
}
