//! System-controller FPGA fabric model (paper §II-C, Fig 5).
//!
//! * [`preprocess`] — the problem-specific preprocessing chain (Fig 7).
//! * [`dma`] — DMA controller + LPDDR4 DRAM model.
//! * [`eventgen`] — vector event generator + lookup table.
//! * [`playback`] — playback/trace buffers + memory switch.
//! * [`link`] — LVDS link layer (bandwidth, framing, fault injection).

pub mod dma;
pub mod eventgen;
pub mod link;
pub mod playback;
pub mod preprocess;
