//! FPGA preprocessing chain (paper Fig 7, §II-C "preprocessing chain").
//!
//! The problem-specific blue blocks of Fig 5, realised as custom RTL on the
//! real system and mirrored bit-exactly by `python/compile/data.py::preprocess`:
//!
//!   1. **discrete derivative** — suppresses baseline fluctuations,
//!   2. **max–min pooling** over `POOL_WINDOW` raw samples — rate reduction
//!      and positive activations,
//!   3. **5-bit quantisation** — a barrel right-shift, clipped to 31.
//!
//! The stage structure is kept explicit (one function per RTL block plus a
//! streaming state machine) because the timing/energy model charges per
//! stage and Fig 7 plots the intermediate signals.

use crate::asic::consts as c;

/// Stage 1: discrete derivative with the first sample as seed
/// (`d[0] = 0`, `d[i] = x[i] - x[i-1]`), per channel.
pub fn derivative(raw: &[u16]) -> Vec<i32> {
    let mut out = Vec::with_capacity(raw.len());
    let mut prev = *raw.first().unwrap_or(&0) as i32;
    for &s in raw {
        out.push(s as i32 - prev);
        prev = s as i32;
    }
    out
}

/// Stage 2: max–min pooling over non-overlapping `POOL_WINDOW` windows.
pub fn maxmin_pool(deriv: &[i32]) -> Vec<i32> {
    deriv
        .chunks_exact(c::POOL_WINDOW)
        .map(|w| {
            let mut mx = i32::MIN;
            let mut mn = i32::MAX;
            for &v in w {
                mx = mx.max(v);
                mn = mn.min(v);
            }
            mx - mn
        })
        .collect()
}

/// Stage 3: 5-bit quantisation by barrel shift.
pub fn quantize5(pooled: &[i32]) -> Vec<u8> {
    pooled
        .iter()
        .map(|&v| ((v >> c::PREPROC_SHIFT).clamp(0, c::X_MAX)) as u8)
        .collect()
}

/// Full chain over a two-channel window: `[ch][W]` raw 12-bit samples to
/// `MODEL_IN` activations (channel-major layout, matching the python mirror
/// and the event-generator lookup table).
pub fn preprocess(raw: &[Vec<u16>]) -> Vec<u8> {
    assert_eq!(raw.len(), c::ECG_CHANNELS);
    let mut acts = Vec::with_capacity(c::MODEL_IN);
    for ch in raw {
        assert_eq!(ch.len(), c::ECG_WINDOW, "window length");
        acts.extend(quantize5(&maxmin_pool(&derivative(ch))));
    }
    acts
}

/// Intermediate signals for Fig 7 (raw, derivative, pooled, activations)
/// of channel 0.
pub struct Fig7Trace {
    pub raw: Vec<u16>,
    pub derivative: Vec<i32>,
    pub pooled: Vec<i32>,
    pub activations: Vec<u8>,
}

pub fn fig7_trace(raw_ch0: &[u16]) -> Fig7Trace {
    let d = derivative(raw_ch0);
    let p = maxmin_pool(&d);
    let a = quantize5(&p);
    Fig7Trace { raw: raw_ch0.to_vec(), derivative: d, pooled: p, activations: a }
}

/// Streaming implementation processing one sample per FPGA clock — the form
/// the RTL actually takes.  Kept semantically identical to the batch chain
/// (property-tested) and used by the DMA path with cycle accounting.
pub struct StreamingPreprocessor {
    prev: i32,
    seeded: bool,
    win_max: i32,
    win_min: i32,
    win_fill: usize,
    pub out: Vec<u8>,
    /// FPGA clock cycles consumed (1/sample + 1/window flush).
    pub cycles: u64,
}

impl Default for StreamingPreprocessor {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingPreprocessor {
    pub fn new() -> Self {
        StreamingPreprocessor {
            prev: 0,
            seeded: false,
            win_max: i32::MIN,
            win_min: i32::MAX,
            win_fill: 0,
            out: Vec::new(),
            cycles: 0,
        }
    }

    pub fn push(&mut self, sample: u16) {
        self.cycles += 1;
        let s = sample as i32;
        if !self.seeded {
            self.prev = s;
            self.seeded = true;
        }
        let d = s - self.prev;
        self.prev = s;
        self.win_max = self.win_max.max(d);
        self.win_min = self.win_min.min(d);
        self.win_fill += 1;
        if self.win_fill == c::POOL_WINDOW {
            let pooled = self.win_max - self.win_min;
            self.out
                .push(((pooled >> c::PREPROC_SHIFT).clamp(0, c::X_MAX)) as u8);
            self.win_max = i32::MIN;
            self.win_min = i32::MAX;
            self.win_fill = 0;
            self.cycles += 1;
        }
    }

    pub fn push_channel(&mut self, raw: &[u16]) {
        for &s in raw {
            self.push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn derivative_basic() {
        assert_eq!(derivative(&[5, 7, 7, 2]), vec![0, 2, 0, -5]);
        assert_eq!(derivative(&[]), Vec::<i32>::new());
    }

    #[test]
    fn maxmin_pool_window() {
        let mut d = vec![0i32; c::POOL_WINDOW * 2];
        d[3] = 10;
        d[5] = -4; // window 0: max 10, min -4 -> 14
        d[c::POOL_WINDOW + 1] = 7; // window 1: 7 - 0 = 7
        assert_eq!(maxmin_pool(&d), vec![14, 7]);
    }

    #[test]
    fn quantize5_shift_and_clip() {
        assert_eq!(quantize5(&[0, 31, 32, 64, 100000]), vec![0, 0, 1, 2, 31]);
    }

    #[test]
    fn full_chain_shapes() {
        let raw = vec![vec![2048u16; c::ECG_WINDOW]; c::ECG_CHANNELS];
        let acts = preprocess(&raw);
        assert_eq!(acts.len(), c::MODEL_IN);
        assert!(acts.iter().all(|&a| a == 0));
    }

    #[test]
    fn spike_lands_in_right_bin() {
        let mut raw = vec![vec![2048u16; c::ECG_WINDOW]; c::ECG_CHANNELS];
        let pos = 20 * c::POOL_WINDOW + 5;
        raw[0][pos] = 3500;
        raw[0][pos + 1] = 3500;
        let acts = preprocess(&raw);
        assert_eq!(acts[20], c::X_MAX as u8);
        assert_eq!(acts[25], 0);
        assert_eq!(acts[c::POOLED_LEN + 20], 0, "channel isolation");
    }

    #[test]
    fn streaming_equals_batch() {
        // Property: the RTL-shaped streaming pipeline == the batch chain.
        let mut rng = SplitMix64::new(42);
        for case in 0..10 {
            let raw: Vec<u16> = (0..c::ECG_WINDOW)
                .map(|_| rng.below(4096) as u16)
                .collect();
            let batch = quantize5(&maxmin_pool(&derivative(&raw)));
            let mut sp = StreamingPreprocessor::new();
            sp.push_channel(&raw);
            assert_eq!(sp.out, batch, "case {case}");
        }
    }

    #[test]
    fn streaming_cycle_count() {
        let mut sp = StreamingPreprocessor::new();
        sp.push_channel(&vec![0u16; c::ECG_WINDOW]);
        let expected = c::ECG_WINDOW as u64 + (c::ECG_WINDOW / c::POOL_WINDOW) as u64;
        assert_eq!(sp.cycles, expected);
    }

    #[test]
    fn fig7_trace_consistent() {
        let mut raw = vec![2048u16; c::ECG_WINDOW];
        raw[100] = 2600;
        let tr = fig7_trace(&raw);
        assert_eq!(tr.derivative.len(), c::ECG_WINDOW);
        assert_eq!(tr.pooled.len(), c::POOLED_LEN);
        assert_eq!(tr.activations, quantize5(&tr.pooled));
    }
}
