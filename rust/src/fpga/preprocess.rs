//! FPGA preprocessing chain (paper Fig 7, §II-C "preprocessing chain").
//!
//! The problem-specific blue blocks of Fig 5, realised as custom RTL on the
//! real system and mirrored bit-exactly by `python/compile/data.py::preprocess`:
//!
//!   1. **discrete derivative** — suppresses baseline fluctuations,
//!   2. **max–min pooling** over `POOL_WINDOW` raw samples — rate reduction
//!      and positive activations,
//!   3. **5-bit quantisation** — a barrel right-shift, clipped to 31.
//!
//! The stage structure is kept explicit (one function per RTL block plus a
//! streaming state machine) because the timing/energy model charges per
//! stage and Fig 7 plots the intermediate signals.

use crate::asic::consts as c;

/// Stage 1: discrete derivative with the first sample as seed
/// (`d[0] = 0`, `d[i] = x[i] - x[i-1]`), per channel.
pub fn derivative(raw: &[u16]) -> Vec<i32> {
    let mut out = Vec::with_capacity(raw.len());
    let mut prev = *raw.first().unwrap_or(&0) as i32;
    for &s in raw {
        out.push(s as i32 - prev);
        prev = s as i32;
    }
    out
}

/// Stage 2: max–min pooling over non-overlapping `POOL_WINDOW` windows.
pub fn maxmin_pool(deriv: &[i32]) -> Vec<i32> {
    deriv
        .chunks_exact(c::POOL_WINDOW)
        .map(|w| {
            let mut mx = i32::MIN;
            let mut mn = i32::MAX;
            for &v in w {
                mx = mx.max(v);
                mn = mn.min(v);
            }
            mx - mn
        })
        .collect()
}

/// Stage 3: 5-bit quantisation by barrel shift.
pub fn quantize5(pooled: &[i32]) -> Vec<u8> {
    pooled
        .iter()
        .map(|&v| ((v >> c::PREPROC_SHIFT).clamp(0, c::X_MAX)) as u8)
        .collect()
}

/// Full chain over a two-channel window: `[ch][W]` raw 12-bit samples to
/// `MODEL_IN` activations (channel-major layout, matching the python mirror
/// and the event-generator lookup table).
pub fn preprocess(raw: &[Vec<u16>]) -> Vec<u8> {
    assert_eq!(raw.len(), c::ECG_CHANNELS);
    let mut acts = Vec::with_capacity(c::MODEL_IN);
    for ch in raw {
        assert_eq!(ch.len(), c::ECG_WINDOW, "window length");
        acts.extend(quantize5(&maxmin_pool(&derivative(ch))));
    }
    acts
}

/// Intermediate signals for Fig 7 (raw, derivative, pooled, activations)
/// of channel 0.
pub struct Fig7Trace {
    pub raw: Vec<u16>,
    pub derivative: Vec<i32>,
    pub pooled: Vec<i32>,
    pub activations: Vec<u8>,
}

pub fn fig7_trace(raw_ch0: &[u16]) -> Fig7Trace {
    let d = derivative(raw_ch0);
    let p = maxmin_pool(&d);
    let a = quantize5(&p);
    Fig7Trace { raw: raw_ch0.to_vec(), derivative: d, pooled: p, activations: a }
}

/// Streaming implementation processing one sample per FPGA clock — the form
/// the RTL actually takes.  Kept semantically identical to the batch chain
/// (property-tested) and used by the DMA path with cycle accounting.
pub struct StreamingPreprocessor {
    prev: i32,
    seeded: bool,
    win_max: i32,
    win_min: i32,
    win_fill: usize,
    pub out: Vec<u8>,
    /// FPGA clock cycles consumed (1/sample + 1/window flush).
    pub cycles: u64,
}

impl Default for StreamingPreprocessor {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingPreprocessor {
    pub fn new() -> Self {
        StreamingPreprocessor {
            prev: 0,
            seeded: false,
            win_max: i32::MIN,
            win_min: i32::MAX,
            win_fill: 0,
            out: Vec::new(),
            cycles: 0,
        }
    }

    pub fn push(&mut self, sample: u16) {
        self.cycles += 1;
        let s = sample as i32;
        if !self.seeded {
            self.prev = s;
            self.seeded = true;
        }
        let d = s - self.prev;
        self.prev = s;
        self.win_max = self.win_max.max(d);
        self.win_min = self.win_min.min(d);
        self.win_fill += 1;
        if self.win_fill == c::POOL_WINDOW {
            let pooled = self.win_max - self.win_min;
            self.out
                .push(((pooled >> c::PREPROC_SHIFT).clamp(0, c::X_MAX)) as u8);
            self.win_max = i32::MIN;
            self.win_min = i32::MAX;
            self.win_fill = 0;
            self.cycles += 1;
        }
    }

    pub fn push_channel(&mut self, raw: &[u16]) {
        for &s in raw {
            self.push(s);
        }
    }
}

/// Pool bins per model window.
pub const WIN_BINS: usize = c::ECG_WINDOW / c::POOL_WINDOW;

/// One ready model window extracted from a continuous stream.
#[derive(Debug, Clone)]
pub struct WindowFrame {
    /// 0-based index of the window within the stream (hop-ordered).
    pub index: u64,
    /// Absolute index of the window's first raw sample.
    pub start_sample: u64,
    /// `MODEL_IN` channel-major 5-bit activations, bit-identical to
    /// [`preprocess`] on the same `ECG_WINDOW` raw samples.
    pub acts: Vec<u8>,
}

/// Metadata of a completed window — [`WindowFrame`] minus the activation
/// payload — returned by the allocation-free
/// [`IncrementalWindower::push_into`].
#[derive(Debug, Clone, Copy)]
pub struct WindowMeta {
    /// 0-based index of the window within the stream (hop-ordered).
    pub index: u64,
    /// Absolute index of the window's first raw sample.
    pub start_sample: u64,
}

/// Per-channel incremental state: derivative seed + the current bin's
/// accumulators + a ring of completed pooled/quantised columns.
struct ChanWindow {
    prev: i32,
    seeded: bool,
    /// True derivative of the current bin's first sample.
    d_first: i32,
    /// Max/min over the current bin's *remaining* samples (1..POOL_WINDOW).
    max_r: i32,
    min_r: i32,
    fill: usize,
    /// Ring of the last `WIN_BINS` completed columns as
    /// `(seeded, interior)` activations — see [`IncrementalWindower`].
    ring: Vec<(u8, u8)>,
}

impl ChanWindow {
    fn new() -> ChanWindow {
        ChanWindow {
            prev: 0,
            seeded: false,
            d_first: 0,
            max_r: i32::MIN,
            min_r: i32::MAX,
            fill: 0,
            ring: vec![(0, 0); WIN_BINS],
        }
    }

    /// Feed one raw sample; returns true when it completed a pool bin
    /// (stored into the ring at `bins_done % WIN_BINS`).
    fn push(&mut self, sample: u16, bins_done: u64) -> bool {
        let s = sample as i32;
        if !self.seeded {
            self.prev = s;
            self.seeded = true;
        }
        let d = s - self.prev;
        self.prev = s;
        if self.fill == 0 {
            self.d_first = d;
        } else {
            self.max_r = self.max_r.max(d);
            self.min_r = self.min_r.min(d);
        }
        self.fill += 1;
        if self.fill < c::POOL_WINDOW {
            return false;
        }
        // Interior variant: the true derivative throughout.  Seeded
        // variant: the bin's first derivative replaced by 0 — exactly
        // what the batch chain computes when this bin opens a window
        // (`derivative` seeds with the window's first sample).  The
        // max/min folds below degrade gracefully when the "rest" is
        // empty (POOL_WINDOW == 1): MIN.max(x) == x, MAX.min(x) == x.
        let interior = self.d_first.max(self.max_r) - self.d_first.min(self.min_r);
        let seeded = self.max_r.max(0) - self.min_r.min(0);
        self.ring[(bins_done % WIN_BINS as u64) as usize] =
            (quant5(seeded), quant5(interior));
        self.max_r = i32::MIN;
        self.min_r = i32::MAX;
        self.fill = 0;
        true
    }
}

fn quant5(pooled: i32) -> u8 {
    ((pooled >> c::PREPROC_SHIFT).clamp(0, c::X_MAX)) as u8
}

/// Incremental sliding-window frontend: turns an unbounded two-channel
/// sample stream into model windows at a hop of `hop` samples, spending
/// **O(hop)** work per window instead of re-running the full
/// `O(ECG_WINDOW)` chain.
///
/// The trick: at a hop that is a multiple of `POOL_WINDOW`, consecutive
/// windows share all but `hop / POOL_WINDOW` pooled columns.  Each column
/// is computed **once** as it streams past and kept in a ring — in two
/// variants, because the batch chain seeds the derivative with the
/// window's first sample (`d[0] = 0`): the *seeded* variant (first
/// in-bin derivative replaced by 0) is used when the column opens a
/// window, the *interior* variant (true streaming derivative) everywhere
/// else.  Emitted frames are therefore bit-identical to [`preprocess`]
/// on the same raw window (property-tested below).
pub struct IncrementalWindower {
    hop_bins: usize,
    chans: Vec<ChanWindow>,
    /// Completed bins per channel (channels advance in lockstep).
    bins_done: u64,
    /// Bin count at which the next window completes.
    next_window_bin: u64,
    windows: u64,
    /// Raw samples consumed (per channel).
    pub samples_in: u64,
    /// Frontend work counter: one op per (channel, sample) + one per
    /// completed column.  The marginal cost per emitted window is exactly
    /// `ECG_CHANNELS * (hop + hop / POOL_WINDOW)` — O(hop), not
    /// O(ECG_WINDOW) (asserted by `benches/stream_monitoring.rs`).
    pub work_ops: u64,
}

impl IncrementalWindower {
    /// `hop` must be a positive multiple of `POOL_WINDOW`, at most
    /// `ECG_WINDOW` (a larger hop would skip samples).
    pub fn new(hop: usize) -> anyhow::Result<IncrementalWindower> {
        anyhow::ensure!(
            hop > 0 && hop <= c::ECG_WINDOW && hop % c::POOL_WINDOW == 0,
            "hop must be a multiple of {} in 1..={}, got {hop}",
            c::POOL_WINDOW,
            c::ECG_WINDOW
        );
        Ok(IncrementalWindower {
            hop_bins: hop / c::POOL_WINDOW,
            chans: (0..c::ECG_CHANNELS).map(|_| ChanWindow::new()).collect(),
            bins_done: 0,
            next_window_bin: WIN_BINS as u64,
            windows: 0,
            samples_in: 0,
            work_ops: 0,
        })
    }

    pub fn hop(&self) -> usize {
        self.hop_bins * c::POOL_WINDOW
    }

    /// Windows emitted so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Feed one sample per channel; returns the completed window, if any.
    /// Allocates the frame's `acts` only when a window actually completes
    /// — hot loops that recycle one buffer across windows use
    /// [`push_into`](Self::push_into) instead.
    pub fn push(&mut self, samples: [u16; c::ECG_CHANNELS]) -> Option<WindowFrame> {
        let mut acts = Vec::new();
        let meta = self.push_into(samples, &mut acts)?;
        Some(WindowFrame {
            index: meta.index,
            start_sample: meta.start_sample,
            acts,
        })
    }

    /// Allocation-free core of [`push`](Self::push): when the sample
    /// completes a window, `acts` is cleared and refilled with its
    /// `MODEL_IN` activations — reusing the buffer's capacity across
    /// windows (DESIGN.md §17) — and the window metadata is returned.
    /// Samples that complete no window leave `acts` untouched.
    pub fn push_into(
        &mut self,
        samples: [u16; c::ECG_CHANNELS],
        acts: &mut Vec<u8>,
    ) -> Option<WindowMeta> {
        self.samples_in += 1;
        self.work_ops += c::ECG_CHANNELS as u64;
        let mut bin_done = false;
        for (ch, &s) in self.chans.iter_mut().zip(samples.iter()) {
            bin_done = ch.push(s, self.bins_done);
        }
        if !bin_done {
            return None;
        }
        self.bins_done += 1;
        self.work_ops += c::ECG_CHANNELS as u64;
        if self.bins_done < self.next_window_bin {
            return None;
        }
        self.next_window_bin += self.hop_bins as u64;
        let start_bin = self.bins_done - WIN_BINS as u64;
        acts.clear();
        acts.reserve(c::MODEL_IN);
        for ch in &self.chans {
            for k in 0..WIN_BINS as u64 {
                let (seeded, interior) =
                    ch.ring[((start_bin + k) % WIN_BINS as u64) as usize];
                acts.push(if k == 0 { seeded } else { interior });
            }
        }
        let meta = WindowMeta {
            index: self.windows,
            start_sample: start_bin * c::POOL_WINDOW as u64,
        };
        self.windows += 1;
        Some(meta)
    }

    /// Feed a two-channel chunk (`chunk[ch]`, equal lengths); returns the
    /// windows it completed, in stream order.
    pub fn push_chunk(
        &mut self,
        chunk: &[Vec<u16>],
    ) -> anyhow::Result<Vec<WindowFrame>> {
        anyhow::ensure!(
            chunk.len() == c::ECG_CHANNELS,
            "need {} channels, got {}",
            c::ECG_CHANNELS,
            chunk.len()
        );
        anyhow::ensure!(
            chunk[0].len() == chunk[1].len(),
            "channel lengths differ: {} vs {}",
            chunk[0].len(),
            chunk[1].len()
        );
        let mut frames = Vec::new();
        for i in 0..chunk[0].len() {
            if let Some(f) = self.push([chunk[0][i], chunk[1][i]]) {
                frames.push(f);
            }
        }
        Ok(frames)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn derivative_basic() {
        assert_eq!(derivative(&[5, 7, 7, 2]), vec![0, 2, 0, -5]);
        assert_eq!(derivative(&[]), Vec::<i32>::new());
    }

    #[test]
    fn maxmin_pool_window() {
        let mut d = vec![0i32; c::POOL_WINDOW * 2];
        d[3] = 10;
        d[5] = -4; // window 0: max 10, min -4 -> 14
        d[c::POOL_WINDOW + 1] = 7; // window 1: 7 - 0 = 7
        assert_eq!(maxmin_pool(&d), vec![14, 7]);
    }

    #[test]
    fn quantize5_shift_and_clip() {
        assert_eq!(quantize5(&[0, 31, 32, 64, 100000]), vec![0, 0, 1, 2, 31]);
    }

    #[test]
    fn full_chain_shapes() {
        let raw = vec![vec![2048u16; c::ECG_WINDOW]; c::ECG_CHANNELS];
        let acts = preprocess(&raw);
        assert_eq!(acts.len(), c::MODEL_IN);
        assert!(acts.iter().all(|&a| a == 0));
    }

    #[test]
    fn spike_lands_in_right_bin() {
        let mut raw = vec![vec![2048u16; c::ECG_WINDOW]; c::ECG_CHANNELS];
        let pos = 20 * c::POOL_WINDOW + 5;
        raw[0][pos] = 3500;
        raw[0][pos + 1] = 3500;
        let acts = preprocess(&raw);
        assert_eq!(acts[20], c::X_MAX as u8);
        assert_eq!(acts[25], 0);
        assert_eq!(acts[c::POOLED_LEN + 20], 0, "channel isolation");
    }

    #[test]
    fn streaming_equals_batch() {
        // Property: the RTL-shaped streaming pipeline == the batch chain.
        let mut rng = SplitMix64::new(42);
        for case in 0..10 {
            let raw: Vec<u16> = (0..c::ECG_WINDOW)
                .map(|_| rng.below(4096) as u16)
                .collect();
            let batch = quantize5(&maxmin_pool(&derivative(&raw)));
            let mut sp = StreamingPreprocessor::new();
            sp.push_channel(&raw);
            assert_eq!(sp.out, batch, "case {case}");
        }
    }

    #[test]
    fn streaming_cycle_count() {
        let mut sp = StreamingPreprocessor::new();
        sp.push_channel(&vec![0u16; c::ECG_WINDOW]);
        let expected = c::ECG_WINDOW as u64 + (c::ECG_WINDOW / c::POOL_WINDOW) as u64;
        assert_eq!(sp.cycles, expected);
    }

    #[test]
    fn incremental_windower_matches_batch_chain() {
        // Property: every frame emitted by the incremental frontend is
        // bit-identical to the batch `preprocess()` of the same raw
        // window — for random streams, hops, and chunkings.
        let mut rng = SplitMix64::new(0x51D1);
        for &hop in &[32usize, 96, 128, 512, 1024, 2048] {
            let total = c::ECG_WINDOW + 3 * hop + 17;
            let raw: Vec<Vec<u16>> = (0..c::ECG_CHANNELS)
                .map(|_| {
                    (0..total).map(|_| rng.below(4096) as u16).collect()
                })
                .collect();
            let mut w = IncrementalWindower::new(hop).unwrap();
            let mut frames = Vec::new();
            let mut fed = 0usize;
            while fed < total {
                let n = (1 + rng.below(701) as usize).min(total - fed);
                let chunk: Vec<Vec<u16>> = raw
                    .iter()
                    .map(|ch| ch[fed..fed + n].to_vec())
                    .collect();
                frames.extend(w.push_chunk(&chunk).unwrap());
                fed += n;
            }
            let expect_windows = (total - c::ECG_WINDOW) / hop + 1;
            assert_eq!(frames.len(), expect_windows, "hop {hop}");
            for (k, f) in frames.iter().enumerate() {
                assert_eq!(f.index, k as u64);
                assert_eq!(f.start_sample, (k * hop) as u64, "hop {hop}");
                let s = f.start_sample as usize;
                let win: Vec<Vec<u16>> = raw
                    .iter()
                    .map(|ch| ch[s..s + c::ECG_WINDOW].to_vec())
                    .collect();
                assert_eq!(
                    f.acts,
                    preprocess(&win),
                    "hop {hop}, window {k} not bit-identical"
                );
            }
        }
    }

    #[test]
    fn incremental_window_cost_is_o_hop() {
        // The marginal work between consecutive windows is exactly
        // 2 · (hop + hop/32) ops — independent of the window length.
        for &hop in &[32usize, 256, 2048] {
            let mut rng = SplitMix64::new(9);
            let mut w = IncrementalWindower::new(hop).unwrap();
            let mut marks = Vec::new();
            for _ in 0..c::ECG_WINDOW + 4 * hop {
                if w.push([rng.below(4096) as u16, rng.below(4096) as u16])
                    .is_some()
                {
                    marks.push(w.work_ops);
                }
            }
            assert!(marks.len() >= 4);
            let per = (c::ECG_CHANNELS * (hop + hop / c::POOL_WINDOW)) as u64;
            for pair in marks.windows(2) {
                assert_eq!(pair[1] - pair[0], per, "hop {hop}");
            }
        }
    }

    #[test]
    fn push_into_matches_push_and_reuses_the_buffer() {
        // The allocation-free core emits bit-identical frames, and one
        // caller-held buffer really is recycled: after the first window
        // sized it, later windows must not reallocate (stable pointer).
        let hop = 4 * c::POOL_WINDOW;
        let mut rng = SplitMix64::new(0xACE5);
        let mut a = IncrementalWindower::new(hop).unwrap();
        let mut b = IncrementalWindower::new(hop).unwrap();
        let mut acts = Vec::new();
        let mut buf_ptr = std::ptr::null();
        let mut windows = 0u64;
        for _ in 0..c::ECG_WINDOW + 6 * hop {
            let s = [rng.below(4096) as u16, rng.below(4096) as u16];
            let want = a.push(s);
            let got = b.push_into(s, &mut acts);
            match (want, got) {
                (None, None) => {}
                (Some(frame), Some(meta)) => {
                    assert_eq!(meta.index, frame.index);
                    assert_eq!(meta.start_sample, frame.start_sample);
                    assert_eq!(acts, frame.acts);
                    if windows == 0 {
                        buf_ptr = acts.as_ptr();
                    } else {
                        assert_eq!(
                            acts.as_ptr(),
                            buf_ptr,
                            "acts buffer reallocated between windows"
                        );
                    }
                    windows += 1;
                }
                (w, g) => panic!(
                    "push/push_into disagree on completion: {:?} vs {:?}",
                    w.map(|f| f.index),
                    g.map(|m| m.index)
                ),
            }
        }
        assert!(windows >= 6, "only {windows} windows emitted");
    }

    #[test]
    fn incremental_windower_rejects_bad_hops() {
        assert!(IncrementalWindower::new(0).is_err());
        assert!(IncrementalWindower::new(33).is_err());
        assert!(IncrementalWindower::new(c::ECG_WINDOW + 32).is_err());
        assert!(IncrementalWindower::new(c::POOL_WINDOW).is_ok());
        let mut w = IncrementalWindower::new(64).unwrap();
        assert!(w.push_chunk(&[vec![1, 2], vec![3]]).is_err(), "ragged");
        assert!(w.push_chunk(&[vec![1, 2]]).is_err(), "one channel");
    }

    #[test]
    fn fig7_trace_consistent() {
        let mut raw = vec![2048u16; c::ECG_WINDOW];
        raw[100] = 2600;
        let tr = fig7_trace(&raw);
        assert_eq!(tr.derivative.len(), c::ECG_WINDOW);
        assert_eq!(tr.pooled.len(), c::POOLED_LEN);
        assert_eq!(tr.activations, quantize5(&tr.pooled));
    }
}
