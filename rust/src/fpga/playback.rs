//! Playback + trace buffers and the memory switch (paper Fig 5).
//!
//! * The **playback buffer** holds a pre-compiled list of commands (events
//!   and register writes) that the FPGA replays to the ASIC with precise
//!   timing.
//! * The **trace buffer** collects events/readout coming back.
//! * The **memory switch** arbitrates memory-mapped access between the
//!   playback path, the ARM cores, and memory requests issued *by the ASIC*
//!   (the SIMD CPUs program the DMA through it, paper §II-C).

use std::collections::VecDeque;

use crate::asic::packets::{Event, MemPacket};

/// A playback entry: release `what` at `release_ns` of experiment time.
#[derive(Debug, Clone)]
pub enum PlaybackCmd {
    Event(Event),
    Mem(MemPacket),
    /// Barrier: wait until the ASIC-side handshake (vector event generator
    /// sync, paper §II-C) fires.
    Sync(u32),
}

/// A compiled program handed an entry that precedes the buffer tail.
///
/// This is a *typed* error, not a panic: the playback buffer runs inside
/// an engine worker thread, and a panic there would take the whole chip
/// worker down.  Returning the error lets the caller surface it as an
/// engine failure, which the fleet health machine counts toward marking
/// the chip unhealthy/faulted instead of crashing the replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
#[error(
    "playback entry at {release_ns} ns precedes the buffer tail at \
     {tail_ns} ns (compiled program out of order)"
)]
pub struct OutOfOrderEntry {
    pub release_ns: u64,
    pub tail_ns: u64,
}

#[derive(Debug, Default)]
pub struct PlaybackBuffer {
    queue: VecDeque<(u64, PlaybackCmd)>,
    pub replayed: u64,
}

impl PlaybackBuffer {
    /// Append a command; entries must be time-sorted (the compiler emits
    /// them in order).  An out-of-order entry is rejected — the buffer is
    /// left untouched so the chip can be drained/faulted cleanly.
    pub fn push(
        &mut self,
        release_ns: u64,
        cmd: PlaybackCmd,
    ) -> Result<(), OutOfOrderEntry> {
        if let Some(&(last, _)) = self.queue.back() {
            if release_ns < last {
                return Err(OutOfOrderEntry {
                    release_ns,
                    tail_ns: last,
                });
            }
        }
        self.queue.push_back((release_ns, cmd));
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pop every command due at or before `now_ns`.
    pub fn due(&mut self, now_ns: u64) -> Vec<PlaybackCmd> {
        let mut out = Vec::new();
        while let Some(&(t, _)) = self.queue.front() {
            if t > now_ns {
                break;
            }
            out.push(self.queue.pop_front().unwrap().1);
            self.replayed += 1;
        }
        out
    }
}

/// Trace buffer: bounded ring of returned events/readouts.
#[derive(Debug)]
pub struct TraceBuffer {
    ring: VecDeque<Event>,
    pub capacity: usize,
    pub overflowed: u64,
}

impl TraceBuffer {
    pub fn new(capacity: usize) -> TraceBuffer {
        TraceBuffer { ring: VecDeque::with_capacity(capacity), capacity, overflowed: 0 }
    }

    pub fn record(&mut self, ev: Event) {
        if self.ring.len() == self.capacity {
            self.ring.pop_front();
            self.overflowed += 1;
        }
        self.ring.push_back(ev);
    }

    pub fn drain(&mut self) -> Vec<Event> {
        self.ring.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
}

/// Memory-switch ports, in fixed arbitration priority order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Port {
    /// Requests issued by the ASIC's SIMD CPUs (highest: the inner loop).
    Asic,
    /// The playback/DMA path.
    Playback,
    /// The ARM cores (initialisation only, paper §II-C).
    Arm,
}

/// Fixed-priority arbiter over queued memory requests.
#[derive(Debug, Default)]
pub struct MemorySwitch {
    queues: [VecDeque<MemPacket>; 3],
    pub granted: [u64; 3],
}

impl MemorySwitch {
    fn idx(port: Port) -> usize {
        match port {
            Port::Asic => 0,
            Port::Playback => 1,
            Port::Arm => 2,
        }
    }

    pub fn request(&mut self, port: Port, pkt: MemPacket) {
        self.queues[Self::idx(port)].push_back(pkt);
    }

    /// Grant the next request by priority; returns (port, packet).
    pub fn grant(&mut self) -> Option<(Port, MemPacket)> {
        for (i, port) in [Port::Asic, Port::Playback, Port::Arm]
            .into_iter()
            .enumerate()
        {
            if let Some(pkt) = self.queues[i].pop_front() {
                self.granted[i] += 1;
                return Some((port, pkt));
            }
        }
        None
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn playback_releases_in_time_order() {
        let mut pb = PlaybackBuffer::default();
        pb.push(10, PlaybackCmd::Event(Event::new(1, 1))).unwrap();
        pb.push(20, PlaybackCmd::Event(Event::new(2, 2))).unwrap();
        pb.push(30, PlaybackCmd::Sync(0)).unwrap();
        assert_eq!(pb.due(5).len(), 0);
        assert_eq!(pb.due(20).len(), 2);
        assert_eq!(pb.due(100).len(), 1);
        assert!(pb.is_empty());
        assert_eq!(pb.replayed, 3);
    }

    #[test]
    fn playback_rejects_unordered_without_panicking() {
        let mut pb = PlaybackBuffer::default();
        pb.push(20, PlaybackCmd::Sync(0)).unwrap();
        let err = pb.push(10, PlaybackCmd::Sync(1)).unwrap_err();
        assert_eq!(err, OutOfOrderEntry { release_ns: 10, tail_ns: 20 });
        assert!(err.to_string().contains("out of order"), "{err}");
        // The buffer is untouched: the ordered entry is still replayable.
        assert_eq!(pb.len(), 1);
        assert_eq!(pb.due(100).len(), 1);
        // Equal timestamps remain legal (back-to-back commands).
        pb.push(40, PlaybackCmd::Sync(2)).unwrap();
        pb.push(40, PlaybackCmd::Sync(3)).unwrap();
        assert_eq!(pb.due(40).len(), 2);
    }

    #[test]
    fn trace_buffer_overflow_drops_oldest() {
        let mut tb = TraceBuffer::new(2);
        tb.record(Event::new(1, 1));
        tb.record(Event::new(2, 2));
        tb.record(Event::new(3, 3));
        assert_eq!(tb.overflowed, 1);
        let evs = tb.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].address, 2);
        assert!(tb.is_empty());
    }

    #[test]
    fn memory_switch_priority() {
        let mut sw = MemorySwitch::default();
        sw.request(Port::Arm, MemPacket::WriteAck { seq: 1 });
        sw.request(Port::Asic, MemPacket::WriteAck { seq: 2 });
        sw.request(Port::Playback, MemPacket::WriteAck { seq: 3 });
        let (p1, k1) = sw.grant().unwrap();
        assert_eq!(p1, Port::Asic);
        assert_eq!(k1.seq(), 2);
        let (p2, _) = sw.grant().unwrap();
        assert_eq!(p2, Port::Playback);
        let (p3, _) = sw.grant().unwrap();
        assert_eq!(p3, Port::Arm);
        assert!(sw.grant().is_none());
        assert_eq!(sw.granted, [1, 1, 1]);
    }

    #[test]
    fn memory_switch_fifo_within_port() {
        let mut sw = MemorySwitch::default();
        sw.request(Port::Asic, MemPacket::WriteAck { seq: 1 });
        sw.request(Port::Asic, MemPacket::WriteAck { seq: 2 });
        assert_eq!(sw.grant().unwrap().1.seq(), 1);
        assert_eq!(sw.grant().unwrap().1.seq(), 2);
    }
}
