//! Link control + physical layer model (paper Fig 5 "link ctrl", "phys").
//!
//! The ASIC exposes eight source-synchronous LVDS channels at up to
//! 2 Gbit/s; five are routed through the adapter PCB to the FPGA (paper
//! §II-B).  The model tracks per-link occupancy to account transfer time
//! and feed the IO-energy estimate, and applies the event-frame parity
//! check of `asic::packets` (corrupted frames are dropped and counted).

use crate::asic::consts as c;
use crate::asic::packets::Event;

#[derive(Debug, Clone)]
pub struct LinkConfig {
    pub links: usize,
    pub gbps: f64,
    /// Bit-error rate for fault-injection tests (0.0 in normal operation).
    pub ber: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig { links: c::LVDS_LINKS, gbps: c::LVDS_GBPS, ber: 0.0 }
    }
}

#[derive(Debug, Default, Clone)]
pub struct LinkStats {
    pub events_tx: u64,
    pub events_dropped: u64,
    pub bits_tx: u64,
    pub busy_ns: f64,
}

/// Round-robin serialiser over the available links.
pub struct LinkLayer {
    pub cfg: LinkConfig,
    pub stats: LinkStats,
    rng: crate::util::rng::SplitMix64,
}

impl LinkLayer {
    pub fn new(cfg: LinkConfig) -> LinkLayer {
        Self::with_seed(cfg, 0xBEEF)
    }

    /// A link with its own corruption stream — the fault injector seeds
    /// one per chip so corrupted-bit positions are decorrelated across
    /// replicas yet bit-identical per (plan seed, chip, burst sequence).
    pub fn with_seed(cfg: LinkConfig, seed: u64) -> LinkLayer {
        LinkLayer {
            cfg,
            stats: LinkStats::default(),
            rng: crate::util::rng::SplitMix64::new(seed),
        }
    }

    /// Adjust the bit-error rate mid-flight (fault windows open/close).
    pub fn set_ber(&mut self, ber: f64) {
        self.cfg.ber = ber;
    }

    /// Transfer an event burst; returns the events that survived the link
    /// (all of them unless `ber > 0`) and accounts time/bits.
    pub fn transfer(&mut self, events: &[Event]) -> Vec<Event> {
        let mut out = Vec::with_capacity(events.len());
        for ev in events {
            let mut wire = ev.to_wire();
            if self.cfg.ber > 0.0 && self.rng.unit() < self.cfg.ber {
                wire[1] ^= 1 << (self.rng.below(8) as u8); // flip a random bit
            }
            match Event::from_wire(wire) {
                Some(dec) => {
                    out.push(dec.at(ev.timestamp_ns));
                    self.stats.events_tx += 1;
                }
                None => self.stats.events_dropped += 1,
            }
            self.stats.bits_tx += Event::WIRE_BITS as u64;
        }
        // Aggregate wire time across parallel links.
        let bits = (events.len() * Event::WIRE_BITS) as f64;
        self.stats.busy_ns += bits / (self.cfg.links as f64 * self.cfg.gbps);
        out
    }

    /// Effective event throughput [events/s] at the configured link budget.
    pub fn peak_event_rate(&self) -> f64 {
        self.cfg.links as f64 * self.cfg.gbps * 1e9 / Event::WIRE_BITS as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_link_delivers_everything() {
        let mut l = LinkLayer::new(LinkConfig::default());
        let evs: Vec<Event> = (0..100).map(|i| Event::new(i, (i % 32) as u8)).collect();
        let got = l.transfer(&evs);
        assert_eq!(got.len(), 100);
        assert_eq!(l.stats.events_dropped, 0);
        assert_eq!(l.stats.bits_tx, 100 * Event::WIRE_BITS as u64);
    }

    #[test]
    fn noisy_link_drops_frames() {
        let mut l = LinkLayer::new(LinkConfig { ber: 1.0, ..Default::default() });
        let evs: Vec<Event> = (0..50).map(|i| Event::new(i, 1)).collect();
        let got = l.transfer(&evs);
        // Every frame has exactly one flipped bit -> parity must catch
        // address/payload corruption (flips in parity bits may survive as
        // valid-but-equal decodes; those keep payload intact).
        for ev in &got {
            let orig = evs.iter().find(|e| e.address == ev.address);
            if let Some(o) = orig {
                assert_eq!(o.payload, ev.payload);
            }
        }
        assert!(l.stats.events_dropped > 25, "dropped {}", l.stats.events_dropped);
    }

    #[test]
    fn busy_time_matches_budget() {
        let mut l = LinkLayer::new(LinkConfig::default());
        let evs: Vec<Event> = (0..1000).map(|i| Event::new(i % 256, 3)).collect();
        l.transfer(&evs);
        let expect = 1000.0 * Event::WIRE_BITS as f64 / (5.0 * 2.0);
        assert!((l.stats.busy_ns - expect).abs() < 1e-6);
    }

    #[test]
    fn peak_rate_paper_budget() {
        let l = LinkLayer::new(LinkConfig::default());
        // 5 links x 2 Gbit/s / 24 bit ≈ 417 Mevent/s >> the 125 MHz row rate.
        assert!(l.peak_event_rate() > 125e6);
    }
}
