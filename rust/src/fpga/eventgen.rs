//! Vector event generator + lookup table (paper §II-C, Fig 5).
//!
//! "After the raw signal data is converted into 5-bit values, the vector
//! event generator attaches an event address from a lookup table. [...]
//! The use of a lookup table inside the FPGA allows arbitrary mapping of
//! input vector elements onto the synapse matrix."
//!
//! The LUT maps activation-vector indices to event addresses understood by
//! the ASIC's event router; zero activations generate no events (no pulse).

use crate::asic::consts as c;
use crate::asic::packets::Event;

/// Lookup table: vector element index -> event address.
#[derive(Debug, Clone)]
pub struct EventLut {
    table: Vec<u16>,
}

impl EventLut {
    /// Identity mapping for array half `half`: element i -> address
    /// `half * K_LOGICAL + i` (matches `router::EventRouter::identity`).
    pub fn identity(half: u8, len: usize) -> EventLut {
        EventLut {
            table: (0..len)
                .map(|i| half as u16 * c::K_LOGICAL as u16 + i as u16)
                .collect(),
        }
    }

    pub fn custom(table: Vec<u16>) -> EventLut {
        EventLut { table }
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    pub fn lookup(&self, idx: usize) -> Option<u16> {
        self.table.get(idx).copied()
    }
}

/// Statistics of one generation burst.
#[derive(Debug, Default, Clone, Copy)]
pub struct GenStats {
    pub elements: usize,
    pub events: usize,
    pub suppressed_zero: usize,
}

/// Generate the event burst for one activation vector.  Events are spaced
/// `EVENT_PERIOD_NS` apart starting at `t0_ns` (the synapse drivers process
/// back-to-back activations at 8 ns, paper §II-A).
pub fn generate(
    acts: &[u8],
    lut: &EventLut,
    t0_ns: u64,
) -> (Vec<Event>, GenStats) {
    assert!(acts.len() <= lut.len(), "LUT shorter than activation vector");
    let mut events = Vec::with_capacity(acts.len());
    let mut stats = GenStats { elements: acts.len(), ..Default::default() };
    let mut t = t0_ns;
    for (i, &a) in acts.iter().enumerate() {
        if a == 0 {
            stats.suppressed_zero += 1;
            continue;
        }
        let addr = lut.lookup(i).expect("checked above");
        events.push(Event::new(addr, a).at(t));
        t += c::EVENT_PERIOD_NS as u64;
        stats.events += 1;
    }
    (events, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_lut_addresses() {
        let lut = EventLut::identity(1, 4);
        assert_eq!(lut.lookup(0), Some(c::K_LOGICAL as u16));
        assert_eq!(lut.lookup(3), Some(c::K_LOGICAL as u16 + 3));
        assert_eq!(lut.lookup(4), None);
    }

    #[test]
    fn zero_activations_suppressed() {
        let lut = EventLut::identity(0, 4);
        let (evs, st) = generate(&[0, 5, 0, 7], &lut, 0);
        assert_eq!(evs.len(), 2);
        assert_eq!(st.suppressed_zero, 2);
        assert_eq!(evs[0].address, 1);
        assert_eq!(evs[0].payload, 5);
        assert_eq!(evs[1].address, 3);
    }

    #[test]
    fn event_spacing_is_8ns() {
        let lut = EventLut::identity(0, 8);
        let (evs, _) = generate(&[1; 8], &lut, 1000);
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.timestamp_ns, 1000 + i as u64 * 8);
        }
    }

    #[test]
    fn custom_lut_permutes() {
        // Arbitrary mapping of vector elements onto the synapse matrix.
        let lut = EventLut::custom(vec![42, 7, 300]);
        let (evs, _) = generate(&[1, 2, 3], &lut, 0);
        let addrs: Vec<u16> = evs.iter().map(|e| e.address).collect();
        assert_eq!(addrs, vec![42, 7, 300]);
    }

    #[test]
    #[should_panic(expected = "LUT shorter")]
    fn short_lut_panics() {
        let lut = EventLut::identity(0, 2);
        let _ = generate(&[1, 1, 1], &lut, 0);
    }
}
