//! Binary ECG dataset reader — the `ecg_*.bin` artifacts written by
//! `python/compile/train.py::write_ecg_bin`.
//!
//! Format (little-endian):
//! ```text
//! u32 magic = 0x45434731 ("ECG1")
//! u32 n_traces, u32 channels, u32 window
//! n_traces x { u8 label; channels*window x u16 sample }
//! ```

use std::io::Read;
use std::path::Path;

use super::gen::Trace;
use crate::asic::consts as c;

pub const MAGIC: u32 = 0x4543_4731;

#[derive(Debug, thiserror::Error)]
pub enum DatasetError {
    #[error("io error reading dataset: {0}")]
    Io(#[from] std::io::Error),
    #[error("bad magic {0:#x} (expected {MAGIC:#x})")]
    BadMagic(u32),
    #[error("truncated dataset file")]
    Truncated,
    #[error("geometry mismatch: file has {ch} ch x {win} window, model \
             expects {exp_ch} x {exp_win}")]
    Geometry { ch: usize, win: usize, exp_ch: usize, exp_win: usize },
}

#[derive(Debug)]
pub struct Dataset {
    pub traces: Vec<Trace>,
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Dataset, DatasetError> {
        let mut raw = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut raw)?;
        Self::parse(&raw)
    }

    pub fn parse(raw: &[u8]) -> Result<Dataset, DatasetError> {
        let rd_u32 = |off: usize| -> Result<u32, DatasetError> {
            raw.get(off..off + 4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .ok_or(DatasetError::Truncated)
        };
        let magic = rd_u32(0)?;
        if magic != MAGIC {
            return Err(DatasetError::BadMagic(magic));
        }
        let n = rd_u32(4)? as usize;
        let ch = rd_u32(8)? as usize;
        let win = rd_u32(12)? as usize;
        if ch != c::ECG_CHANNELS || win != c::ECG_WINDOW {
            return Err(DatasetError::Geometry {
                ch,
                win,
                exp_ch: c::ECG_CHANNELS,
                exp_win: c::ECG_WINDOW,
            });
        }
        let mut off = 16;
        let mut traces = Vec::with_capacity(n);
        for _ in 0..n {
            let label = *raw.get(off).ok_or(DatasetError::Truncated)?;
            off += 1;
            let mut samples = Vec::with_capacity(ch);
            for _ in 0..ch {
                let mut chan = Vec::with_capacity(win);
                for _ in 0..win {
                    let b = raw
                        .get(off..off + 2)
                        .ok_or(DatasetError::Truncated)?;
                    chan.push(u16::from_le_bytes(b.try_into().unwrap()));
                    off += 2;
                }
                samples.push(chan);
            }
            traces.push(Trace { samples, label });
        }
        Ok(Dataset { traces })
    }

    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.traces.is_empty()
    }

    pub fn afib_fraction(&self) -> f64 {
        if self.traces.is_empty() {
            return 0.0;
        }
        self.traces.iter().filter(|t| t.label == 1).count() as f64
            / self.traces.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(traces: &[Trace]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(MAGIC.to_le_bytes());
        out.extend((traces.len() as u32).to_le_bytes());
        out.extend((c::ECG_CHANNELS as u32).to_le_bytes());
        out.extend((c::ECG_WINDOW as u32).to_le_bytes());
        for t in traces {
            out.push(t.label);
            for ch in &t.samples {
                for &s in ch {
                    out.extend(s.to_le_bytes());
                }
            }
        }
        out
    }

    #[test]
    fn roundtrip() {
        let t0 = super::super::gen::generate_trace(1, false, 1.0);
        let t1 = super::super::gen::generate_trace(2, true, 1.0);
        let blob = encode(&[t0.clone(), t1.clone()]);
        let ds = Dataset::parse(&blob).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.traces[0].samples, t0.samples);
        assert_eq!(ds.traces[1].label, 1);
        assert_eq!(ds.afib_fraction(), 0.5);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut blob = encode(&[]);
        blob[0] ^= 0xFF;
        assert!(matches!(
            Dataset::parse(&blob),
            Err(DatasetError::BadMagic(_))
        ));
    }

    #[test]
    fn truncation_rejected() {
        let t = super::super::gen::generate_trace(3, false, 1.0);
        let blob = encode(&[t]);
        assert!(matches!(
            Dataset::parse(&blob[..blob.len() - 10]),
            Err(DatasetError::Truncated)
        ));
        assert!(matches!(
            Dataset::parse(&blob[..8]),
            Err(DatasetError::Truncated)
        ));
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let mut blob = encode(&[]);
        blob[8..12].copy_from_slice(&5u32.to_le_bytes()); // channels = 5
        assert!(matches!(
            Dataset::parse(&blob),
            Err(DatasetError::Geometry { .. })
        ));
    }
}
