//! Synthetic ECG workload (substitute for the private BMBF dataset).
//!
//! * [`gen`] — windowed generator, mirror of `python/compile/data.py`.
//! * [`stream`] — continuous episode-labeled stream source (the
//!   monitoring scenario: afib episodes crossing window boundaries).
//! * [`dataset`] — reader for the binary artifact sets (`ecg_*.bin`).

pub mod dataset;
pub mod gen;
pub mod stream;
