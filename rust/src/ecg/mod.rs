//! Synthetic ECG workload (substitute for the private BMBF dataset).
//!
//! * [`gen`] — streaming generator, mirror of `python/compile/data.py`.
//! * [`dataset`] — reader for the binary artifact sets (`ecg_*.bin`).

pub mod dataset;
pub mod gen;
