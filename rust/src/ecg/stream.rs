//! Continuous two-channel ECG source with labeled rhythm *episodes*.
//!
//! [`gen::generate_trace`](super::gen::generate_trace) makes isolated
//! 2048-sample windows — fine for the paper's pre-cut benchmark sets, but
//! real ECG monitoring is an unbroken 150 Hz stream in which arrhythmia
//! episodes start and stop wherever they like, crossing every window
//! boundary (the scenario targeted by event-driven neuromorphic ECG
//! monitors, Bauer et al. 2019).  This module emits such a stream: sinus
//! rhythm and atrial-fibrillation *segments* alternate with random
//! durations, the morphology (P/Q/R/S/T bumps, fibrillatory wave,
//! baseline wander, sensor noise) matches the windowed generator, and the
//! ground-truth episode intervals are exposed for latency measurements.
//!
//! Determinism: the generator is **chunking-invariant** — the emitted
//! sample sequence depends only on the seed, never on how the consumer
//! slices its reads.  Each stochastic component (segment schedule, beat
//! timing, sensor noise) draws from its own seeded SplitMix64 stream, so
//! interleaving order cannot perturb any of them.

use std::collections::VecDeque;

use crate::asic::consts as c;
use crate::util::rng::SplitMix64;

use super::gen::{FULL_SCALE_MV, MID, WAVES};

/// Furthest a beat's bumps reach *behind* its R-peak [s]: the P wave sits
/// at -0.18 · 0.8 s with a ±4σ support of 0.1 s.
const BEAT_BACK_S: f64 = 0.25;
/// Furthest a beat's bumps reach *ahead* of its R-peak [s]: the T wave at
/// +0.22 · 0.8 s with ±4σ of 0.24 s.
const BEAT_FWD_S: f64 = 0.45;
/// Synthesis lookahead [samples]: a sample is final only once every beat
/// that could touch it has been placed, i.e. once the buffer extends
/// `BEAT_BACK_S + BEAT_FWD_S` (0.7 s ≈ 105 samples) past it; padded a
/// little for rounding slack.
const COMPLETE_MARGIN: usize =
    ((BEAT_BACK_S + BEAT_FWD_S) * c::ECG_FS_HZ) as usize + 15;
/// Sensor-noise block length [samples] (matches the windowed generator).
const NOISE_BLOCK: u64 = 8;

/// One rhythm interval `[start, end)` in absolute stream samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Episode {
    pub start: u64,
    pub end: u64,
    pub afib: bool,
}

impl Episode {
    /// Length in samples.
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end == self.start
    }
}

/// Episode schedule knobs (durations in seconds).
#[derive(Debug, Clone)]
pub struct EpisodeConfig {
    /// Guaranteed sinus rhythm at the start of the stream (detector
    /// calibration window for the monitoring demo).
    pub lead_in_s: f64,
    /// Sinus segment duration range (uniform).
    pub sinus_s: (f64, f64),
    /// A-fib episode duration range (uniform).
    pub afib_s: (f64, f64),
}

impl Default for EpisodeConfig {
    fn default() -> Self {
        // Defaults make every afib episode span multiple 2048-sample
        // (≈ 13.7 s) windows and every boundary land mid-window.
        EpisodeConfig {
            lead_in_s: 30.0,
            sinus_s: (25.0, 60.0),
            afib_s: (15.0, 40.0),
        }
    }
}

/// Per-segment synthesis parameters (drawn once when the segment is
/// scheduled, from the schedule RNG stream).
#[derive(Debug, Clone)]
struct Segment {
    start: u64,
    end: u64,
    afib: bool,
    /// Base RR interval [s] from the segment's heart rate.
    base_rr: f64,
    /// Respiratory sinus arrhythmia (sinus segments).
    resp_f: f64,
    resp_phase: f64,
    /// Fibrillatory wave (afib segments).
    fib_amp: f64,
    fib_freq: f64,
    fib_phase: f64,
}

/// Unbounded continuous ECG generator.  Pull samples with
/// [`next_chunk`](ContinuousEcg::next_chunk); query ground truth with
/// [`episodes`](ContinuousEcg::episodes) / [`afib_fraction`](ContinuousEcg::afib_fraction).
pub struct ContinuousEcg {
    difficulty: f64,
    cfg: EpisodeConfig,
    // Independent stochastic streams (chunking invariance).
    seg_rng: SplitMix64,
    beat_rng: SplitMix64,
    noise_rng: SplitMix64,
    // Stream-level morphology.
    amp_scale: f64,
    wave_jitter: [f64; 5],
    bw_amp: f64,
    bw_f: f64,
    bw_phase: f64,
    noise_sigma: f64,
    // Segment schedule (grows on demand; strictly contiguous).
    segments: Vec<Segment>,
    // Beat engine.
    next_beat_t: f64,
    // Sensor-noise block state.
    next_noise_block: u64,
    cur_noise: [f64; 2],
    // Signal buffer: buf[i] holds sample `buf_start + i` (mV, per channel).
    buf_start: u64,
    buf: VecDeque<[f64; 2]>,
    emitted: u64,
}

impl ContinuousEcg {
    pub fn new(seed: u64, difficulty: f64, cfg: EpisodeConfig) -> ContinuousEcg {
        let mut morph = SplitMix64::new(seed ^ 0x00C0_FFEE_0001);
        let amp_scale = morph.uniform(0.8, 1.2);
        let mut wave_jitter = [1.0f64; 5];
        for j in wave_jitter.iter_mut() {
            *j = 1.0 + 0.15 * morph.gauss();
        }
        let bw_amp = morph.uniform(0.05, 0.30);
        let bw_f = morph.uniform(0.15, 0.45);
        let bw_phase = morph.uniform(0.0, 2.0 * std::f64::consts::PI);
        let noise_sigma =
            morph.uniform(0.015, 0.035) * (1.0 + 0.5 * difficulty);
        let mut beat_rng = SplitMix64::new(seed ^ 0x00BE_A700_0002);
        let next_beat_t = beat_rng.uniform(0.0, 0.5);
        ContinuousEcg {
            difficulty,
            cfg,
            seg_rng: SplitMix64::new(seed ^ 0x005E_6000_0003),
            beat_rng,
            noise_rng: SplitMix64::new(seed ^ 0x0001_5E00_0004),
            amp_scale,
            wave_jitter,
            bw_amp,
            bw_f,
            bw_phase,
            noise_sigma,
            segments: Vec::new(),
            next_beat_t,
            next_noise_block: 0,
            cur_noise: [0.0; 2],
            buf_start: 0,
            buf: VecDeque::new(),
            emitted: 0,
        }
    }

    /// Samples handed out so far (the absolute index of the next sample).
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// The episode schedule as scheduled so far (extends slightly past the
    /// synthesized signal: segments are drawn ahead of the sample cursor).
    pub fn episodes(&self) -> Vec<Episode> {
        self.segments
            .iter()
            .map(|s| Episode { start: s.start, end: s.end, afib: s.afib })
            .collect()
    }

    /// Fraction of `[start, start + len)` covered by afib episodes.
    /// Extends the schedule on demand, so any future range is valid.
    pub fn afib_fraction(&mut self, start: u64, len: u64) -> f64 {
        if len == 0 {
            return 0.0;
        }
        let end = start + len;
        self.ensure_segments(end);
        let mut afib = 0u64;
        for s in &self.segments {
            if s.afib {
                afib += s.end.min(end).saturating_sub(s.start.max(start));
            }
        }
        afib as f64 / len as f64
    }

    /// Emit the next `n` samples as `[channel][n]` 12-bit values.
    pub fn next_chunk(&mut self, n: usize) -> Vec<Vec<u16>> {
        self.synthesize_to(self.emitted + n as u64);
        let mut out = vec![Vec::with_capacity(n); c::ECG_CHANNELS];
        for _ in 0..n {
            let v = self.buf.pop_front().expect("synthesized range");
            self.buf_start += 1;
            for (ch, chan) in out.iter_mut().enumerate() {
                chan.push(digitize(v[ch]));
            }
        }
        self.emitted += n as u64;
        out
    }

    // --- synthesis ---------------------------------------------------------

    /// Extend the segment schedule to cover at least `sample`.
    fn ensure_segments(&mut self, sample: u64) {
        let fs = c::ECG_FS_HZ;
        while self
            .segments
            .last()
            .map(|s| s.end <= sample)
            .unwrap_or(true)
        {
            let (start, afib) = match self.segments.last() {
                None => (0, false), // sinus lead-in
                Some(prev) => (prev.end, !prev.afib),
            };
            let dur_s = match (self.segments.is_empty(), afib) {
                (true, _) => self.cfg.lead_in_s,
                (false, false) => {
                    self.seg_rng.uniform(self.cfg.sinus_s.0, self.cfg.sinus_s.1)
                }
                (false, true) => {
                    self.seg_rng.uniform(self.cfg.afib_s.0, self.cfg.afib_s.1)
                }
            };
            let len = ((dur_s * fs).round() as u64).max(1);
            let hr = if afib {
                self.seg_rng.uniform(75.0, 135.0)
            } else {
                self.seg_rng.uniform(55.0, 92.0)
            };
            let seg = Segment {
                start,
                end: start + len,
                afib,
                base_rr: 60.0 / hr,
                resp_f: self.seg_rng.uniform(0.15, 0.35),
                resp_phase: self.seg_rng.uniform(0.0, 2.0 * std::f64::consts::PI),
                fib_amp: self.seg_rng.uniform(0.06, 0.18),
                fib_freq: self.seg_rng.uniform(4.0, 9.0),
                fib_phase: self.seg_rng.uniform(0.0, 2.0 * std::f64::consts::PI),
            };
            self.segments.push(seg);
        }
    }

    fn segment_at(&self, sample: u64) -> &Segment {
        let i = self.segments.partition_point(|s| s.end <= sample);
        &self.segments[i.min(self.segments.len() - 1)]
    }

    /// Make every sample `< upto` final: extend the baseline buffer
    /// `COMPLETE_MARGIN` past it, then place every beat whose bumps fit
    /// entirely inside the extended buffer.
    fn synthesize_to(&mut self, upto: u64) {
        let fs = c::ECG_FS_HZ;
        let target = upto + COMPLETE_MARGIN as u64;
        let cur_end = self.buf_start + self.buf.len() as u64;
        if target > cur_end {
            self.ensure_segments(target);
            for i in cur_end..target {
                let t = i as f64 / fs;
                // Sensor noise: one draw per channel per 8-sample block.
                while i / NOISE_BLOCK >= self.next_noise_block {
                    self.cur_noise =
                        [self.noise_rng.gauss(), self.noise_rng.gauss()];
                    self.next_noise_block += 1;
                }
                let w = self.bw_amp
                    * (2.0 * std::f64::consts::PI * self.bw_f * t
                        + self.bw_phase)
                        .sin();
                let mut v = [
                    w + self.noise_sigma * self.cur_noise[0],
                    0.9 * w + self.noise_sigma * self.cur_noise[1],
                ];
                let seg = self.segment_at(i);
                if seg.afib {
                    let mut fib = seg.fib_amp
                        * (2.0 * std::f64::consts::PI * seg.fib_freq * t
                            + seg.fib_phase)
                            .sin();
                    fib *= 1.0
                        + 0.3
                            * (2.0 * std::f64::consts::PI * 0.9 * t
                                + seg.fib_phase * 0.7)
                                .sin();
                    v[0] += fib;
                    v[1] += 0.8 * fib;
                }
                self.buf.push_back(v);
            }
        }
        // Place beats whose full support fits inside the buffer.
        let buf_end_t = (self.buf_start + self.buf.len() as u64) as f64 / fs;
        while self.next_beat_t + BEAT_FWD_S <= buf_end_t {
            self.place_next_beat();
        }
    }

    fn place_next_beat(&mut self) {
        let fs = c::ECG_FS_HZ;
        let bt = self.next_beat_t;
        let bt_sample = (bt * fs) as u64;
        self.ensure_segments(bt_sample);
        let seg = self.segment_at(bt_sample).clone();

        // Per-beat amplitude and the next RR interval (mirrors
        // `gen::beat_times`, parameterised by the segment's rhythm).
        let (rr, bamp);
        if seg.afib {
            let jitter = 0.45 - 0.20 * self.difficulty * self.beat_rng.unit();
            rr = (seg.base_rr
                * (1.0 + jitter * (2.0 * self.beat_rng.unit() - 1.0)))
                .max(0.30);
            bamp = 1.0 + 0.30 * self.beat_rng.gauss();
        } else {
            let rsa = 0.04
                * (2.0 * std::f64::consts::PI * seg.resp_f * bt
                    + seg.resp_phase)
                    .sin();
            let ectopic = if self.beat_rng.unit() < 0.04 * self.difficulty {
                0.25 * (2.0 * self.beat_rng.unit() - 1.0)
            } else {
                0.0
            };
            rr = seg.base_rr
                * (1.0 + rsa + 0.015 * self.beat_rng.gauss() + ectopic);
            bamp = 1.0 + 0.05 * self.beat_rng.gauss();
        }
        let bamp = bamp.clamp(0.35, 1.8);
        self.next_beat_t = bt + rr;

        let rr_local = 0.8;
        for (wi, &(name, off, width, amp, ch1s)) in WAVES.iter().enumerate() {
            if name == "P" && seg.afib {
                continue; // no organised atrial activity during afib
            }
            let a0 = amp * self.amp_scale * bamp * self.wave_jitter[wi];
            let cpos = bt + off * rr_local;
            let lo = (((cpos - 4.0 * width) * fs).floor().max(0.0)) as u64;
            let hi = (((cpos + 4.0 * width) * fs).ceil().max(0.0)) as u64 + 1;
            let buf_end = self.buf_start + self.buf.len() as u64;
            let (lo, hi) = (lo.max(self.buf_start), hi.min(buf_end));
            for i in lo..hi {
                let tt = i as f64 / fs - cpos;
                let bump = a0 * (-0.5 * (tt / width).powi(2)).exp();
                let slot = &mut self.buf[(i - self.buf_start) as usize];
                slot[0] += bump;
                slot[1] += ch1s * bump;
            }
        }
    }
}

fn digitize(v: f64) -> u16 {
    ((v / FULL_SCALE_MV * MID as f64).round() as i32 + MID).clamp(0, 4095)
        as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::preprocess;

    fn short_cfg() -> EpisodeConfig {
        EpisodeConfig {
            lead_in_s: 8.0,
            sinus_s: (6.0, 10.0),
            afib_s: (5.0, 9.0),
        }
    }

    #[test]
    fn chunking_invariant() {
        let mut a = ContinuousEcg::new(7, 1.0, short_cfg());
        let mut b = ContinuousEcg::new(7, 1.0, short_cfg());
        let whole = a.next_chunk(3000);
        let mut sliced = vec![Vec::new(), Vec::new()];
        for n in [1usize, 999, 41, 700, 1259] {
            let ch = b.next_chunk(n);
            for c in 0..2 {
                sliced[c].extend_from_slice(&ch[c]);
            }
        }
        assert_eq!(whole, sliced, "stream must not depend on chunk sizes");
    }

    #[test]
    fn lead_in_is_sinus_and_episodes_alternate() {
        // Afib durations of 14–20 s exceed the 13.7 s model window, so
        // every afib episode *necessarily* spans window boundaries.
        let cfg = EpisodeConfig {
            lead_in_s: 8.0,
            sinus_s: (6.0, 10.0),
            afib_s: (14.0, 20.0),
        };
        let mut s = ContinuousEcg::new(11, 1.0, cfg);
        let _ = s.next_chunk(60 * 150); // one minute
        let eps = s.episodes();
        assert!(!eps[0].afib, "lead-in must be sinus");
        assert_eq!(eps[0].len(), (8.0 * 150.0) as u64);
        for w in eps.windows(2) {
            assert_eq!(w[0].end, w[1].start, "contiguous schedule");
            assert_ne!(w[0].afib, w[1].afib, "alternating rhythm");
        }
        assert!(
            eps.iter().filter(|e| e.afib).count() >= 2,
            "a minute of short segments holds afib episodes: {eps:?}"
        );
        assert!(
            eps.iter()
                .filter(|e| e.afib)
                .all(|e| e.len() as usize > c::ECG_WINDOW),
            "afib episodes must span window boundaries: {eps:?}"
        );
    }

    #[test]
    fn afib_fraction_matches_schedule() {
        let mut s = ContinuousEcg::new(13, 1.0, short_cfg());
        let lead = (8.0 * 150.0) as u64;
        assert_eq!(s.afib_fraction(0, lead), 0.0);
        let eps = s.episodes();
        let first_afib = eps.iter().find(|e| e.afib).unwrap();
        assert_eq!(s.afib_fraction(first_afib.start, first_afib.len()), 1.0);
        // A range straddling the onset is partially covered.
        let f = s.afib_fraction(first_afib.start - 100, 200);
        assert!((f - 0.5).abs() < 1e-9, "{f}");
    }

    #[test]
    fn samples_in_range_with_beats() {
        let mut s = ContinuousEcg::new(3, 1.0, short_cfg());
        let ch = s.next_chunk(c::ECG_WINDOW);
        assert_eq!(ch.len(), c::ECG_CHANNELS);
        assert_eq!(ch[0].len(), c::ECG_WINDOW);
        assert!(ch[0].iter().all(|&v| v <= 4095));
        let max = *ch[0].iter().max().unwrap() as i32;
        let min = *ch[0].iter().min().unwrap() as i32;
        assert!(max - min > 200, "no QRS deflections: {}", max - min);
    }

    #[test]
    fn afib_windows_have_higher_activation() {
        // Streamed counterpart of `gen::tests::class_statistics_differ`:
        // windows lying fully inside afib episodes carry more derivative
        // energy than pure sinus windows.  Segments of 16–26 s leave
        // whole 13.7 s windows inside *both* rhythm classes.
        let cfg = EpisodeConfig {
            lead_in_s: 16.0,
            sinus_s: (16.0, 26.0),
            afib_s: (16.0, 26.0),
        };
        let mut s = ContinuousEcg::new(21, 1.0, cfg);
        let total = 150 * 240; // four minutes
        let raw = s.next_chunk(total);
        let (mut afib_sum, mut afib_n) = (0.0, 0);
        let (mut sinus_sum, mut sinus_n) = (0.0, 0);
        let mut start = 0usize;
        while start + c::ECG_WINDOW <= total {
            let frac =
                s.afib_fraction(start as u64, c::ECG_WINDOW as u64);
            if frac > 0.95 || frac < 0.05 {
                let win: Vec<Vec<u16>> = (0..2)
                    .map(|ch| raw[ch][start..start + c::ECG_WINDOW].to_vec())
                    .collect();
                let acts = preprocess::preprocess(&win);
                let mean = acts.iter().map(|&a| a as f64).sum::<f64>()
                    / acts.len() as f64;
                if frac > 0.95 {
                    afib_sum += mean;
                    afib_n += 1;
                } else {
                    sinus_sum += mean;
                    sinus_n += 1;
                }
            }
            start += 512;
        }
        assert!(afib_n >= 3 && sinus_n >= 3, "{afib_n} afib / {sinus_n} sinus");
        let (am, sm) = (afib_sum / afib_n as f64, sinus_sum / sinus_n as f64);
        assert!(am > sm + 0.2, "afib mean act {am:.3} vs sinus {sm:.3}");
    }
}
