//! Synthetic two-channel ECG generator — the rust mirror of
//! `python/compile/data.py` (same SplitMix64 streams, same morphology).
//!
//! The python side generates the training/held-out test sets exported as
//! binary artifacts; this generator supplies *unlimited streaming* workloads
//! for the serving examples and benches with statistically identical
//! traces.  The distributional contract (class statistics, 12-bit framing)
//! is tested here and cross-checked against the artifact sets in the
//! integration tests.

use crate::asic::consts as c;
use crate::util::rng::SplitMix64;

pub const MID: i32 = 2048;
pub const FULL_SCALE_MV: f64 = 2.5;

/// (center offset [fraction of RR], width [s], amplitude ch0 [mV], ch1 scale)
/// Shared with the continuous generator ([`super::stream`]) so windowed
/// and streamed morphology can never drift apart.
pub(crate) const WAVES: [(&str, f64, f64, f64, f64); 5] = [
    ("P", -0.18, 0.025, 0.12, 0.7),
    ("Q", -0.03, 0.010, -0.14, 1.3),
    ("R", 0.00, 0.012, 1.10, 0.55),
    ("S", 0.03, 0.011, -0.22, 1.6),
    ("T", 0.22, 0.060, 0.28, 0.8),
];

/// One generated trace: 12-bit samples `[channel][sample]` + label.
#[derive(Debug, Clone)]
pub struct Trace {
    pub samples: Vec<Vec<u16>>,
    pub label: u8, // 0 = sinus rhythm, 1 = atrial fibrillation
}

/// R-peak times + per-beat amplitude factors (mirror of `_beat_times`).
fn beat_times(
    rng: &mut SplitMix64,
    afib: bool,
    duration: f64,
    difficulty: f64,
) -> Vec<(f64, f64)> {
    let hr = if afib {
        rng.uniform(75.0, 135.0)
    } else {
        rng.uniform(55.0, 92.0)
    };
    let base_rr = 60.0 / hr;
    let resp_f = rng.uniform(0.15, 0.35);
    let resp_phase = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
    let mut beats = Vec::new();
    let mut t = rng.uniform(0.0, 0.5);
    while t < duration {
        let (rr, amp);
        if afib {
            let jitter = 0.45 - 0.20 * difficulty * rng.unit();
            rr = (base_rr * (1.0 + jitter * (2.0 * rng.unit() - 1.0))).max(0.30);
            amp = 1.0 + 0.30 * rng.gauss();
        } else {
            let rsa = 0.04
                * (2.0 * std::f64::consts::PI * resp_f * t + resp_phase).sin();
            let ectopic = if rng.unit() < 0.04 * difficulty {
                0.25 * (2.0 * rng.unit() - 1.0)
            } else {
                0.0
            };
            rr = base_rr * (1.0 + rsa + 0.015 * rng.gauss() + ectopic);
            amp = 1.0 + 0.05 * rng.gauss();
        }
        beats.push((t, amp.clamp(0.35, 1.8)));
        t += rr;
    }
    beats
}

/// Generate one two-channel 12-bit ECG window (mirror of `generate_trace`).
pub fn generate_trace(seed: u64, afib: bool, difficulty: f64) -> Trace {
    let n = c::ECG_WINDOW;
    let fs = c::ECG_FS_HZ;
    let mut rng = SplitMix64::new(seed);
    let duration = n as f64 / fs;
    let mut sig = vec![vec![0.0f64; n]; 2];

    let beats = beat_times(&mut rng, afib, duration + 1.0, difficulty);
    let amp_scale = rng.uniform(0.8, 1.2);
    let p_amp = if afib { 0.0 } else { 1.0 };
    // Morphology jitter per trace (python iterates WAVES in dict order,
    // which is insertion order P,Q,R,S,T — ours matches).
    let wave_jitter: Vec<f64> =
        (0..WAVES.len()).map(|_| 1.0 + 0.15 * rng.gauss()).collect();

    for &(bt, bamp) in &beats {
        let rr_local = 0.8;
        for (wi, &(name, off, width, amp, ch1s)) in WAVES.iter().enumerate() {
            if name == "P" && afib {
                continue;
            }
            let a0 = amp
                * amp_scale
                * bamp
                * wave_jitter[wi]
                * if name == "P" { p_amp } else { 1.0 };
            let cpos = bt + off * rr_local;
            let lo = (((cpos - 4.0 * width) * fs).floor().max(0.0)) as usize;
            let hi = ((((cpos + 4.0 * width) * fs) as isize) + 1)
                .clamp(0, n as isize) as usize;
            if hi <= lo {
                continue;
            }
            for i in lo..hi {
                let tt = i as f64 / fs - cpos;
                let bump = (-0.5 * (tt / width).powi(2)).exp();
                sig[0][i] += a0 * bump;
                sig[1][i] += a0 * ch1s * bump;
            }
        }
    }

    if afib {
        let f_amp = rng.uniform(0.06, 0.18);
        let f_freq = rng.uniform(4.0, 9.0);
        let f_phase = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
        for i in 0..n {
            let t = i as f64 / fs;
            let mut fib = f_amp
                * (2.0 * std::f64::consts::PI * f_freq * t + f_phase).sin();
            fib *= 1.0
                + 0.3 * (2.0 * std::f64::consts::PI * 0.9 * t + f_phase * 0.7)
                    .sin();
            sig[0][i] += fib;
            sig[1][i] += 0.8 * fib;
        }
    }

    let bw_amp = rng.uniform(0.05, 0.30);
    let bw_f = rng.uniform(0.15, 0.45);
    let bw_phase = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
    for i in 0..n {
        let t = i as f64 / fs;
        let w = bw_amp * (2.0 * std::f64::consts::PI * bw_f * t + bw_phase).sin();
        sig[0][i] += w;
        sig[1][i] += 0.9 * w;
    }

    let noise_sigma = rng.uniform(0.015, 0.035) * (1.0 + 0.5 * difficulty);
    for ch in 0..2 {
        let nblocks = n / 8;
        let nvec: Vec<f64> = (0..nblocks).map(|_| rng.gauss()).collect();
        for i in 0..n {
            sig[ch][i] += noise_sigma * nvec[(i / 8).min(nblocks - 1)];
        }
    }
    if rng.unit() < 0.15 {
        let pos = rng.uniform(0.0, (n - 40) as f64) as usize;
        let spike = rng.uniform(-0.8, 0.8);
        for ch in 0..2 {
            for i in pos..pos + 20 {
                sig[ch][i] += spike;
            }
        }
    }

    let samples = sig
        .into_iter()
        .map(|ch| {
            ch.into_iter()
                .map(|v| {
                    ((v / FULL_SCALE_MV * MID as f64).round() as i32 + MID)
                        .clamp(0, 4095) as u16
                })
                .collect()
        })
        .collect();
    Trace { samples, label: afib as u8 }
}

/// Streaming workload source with the same seed schedule as
/// `data.generate_dataset` (alternating labels).
pub struct TraceStream {
    pub seed: u64,
    pub difficulty: f64,
    next_idx: u64,
}

impl TraceStream {
    pub fn new(seed: u64, difficulty: f64) -> TraceStream {
        TraceStream { seed, difficulty, next_idx: 0 }
    }
}

impl Iterator for TraceStream {
    type Item = Trace;

    fn next(&mut self) -> Option<Trace> {
        let i = self.next_idx;
        self.next_idx += 1;
        let afib = i % 2 == 1;
        Some(generate_trace(
            self.seed.wrapping_mul(1_000_003).wrapping_add(i.wrapping_mul(97)),
            afib,
            self.difficulty,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::preprocess;

    #[test]
    fn trace_shape_and_range() {
        let t = generate_trace(5, false, 1.0);
        assert_eq!(t.samples.len(), c::ECG_CHANNELS);
        assert_eq!(t.samples[0].len(), c::ECG_WINDOW);
        assert!(t.samples[0].iter().all(|&s| s <= 4095));
        assert_eq!(t.label, 0);
    }

    #[test]
    fn determinism() {
        let a = generate_trace(123, true, 1.0);
        let b = generate_trace(123, true, 1.0);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.label, 1);
    }

    #[test]
    fn beats_present() {
        let t = generate_trace(9, false, 1.0);
        let max = *t.samples[0].iter().max().unwrap() as i32;
        let min = *t.samples[0].iter().min().unwrap() as i32;
        assert!(max - min > 200, "no QRS deflections: {}", max - min);
    }

    #[test]
    fn class_statistics_differ() {
        // Same check as python/tests/test_data.py::test_class_statistics_differ.
        let mut mean0 = 0.0;
        let mut mean1 = 0.0;
        let n = 30;
        for i in 0..n {
            for (afib, acc) in [(false, &mut mean0), (true, &mut mean1)] {
                let t = generate_trace(5000 + i * 13 + afib as u64, afib, 1.0);
                let acts = preprocess::preprocess(&t.samples);
                *acc += acts.iter().map(|&a| a as f64).sum::<f64>()
                    / acts.len() as f64;
            }
        }
        mean0 /= n as f64;
        mean1 /= n as f64;
        assert!(
            mean1 > mean0 + 0.5,
            "afib mean act {mean1} vs sinus {mean0}"
        );
    }

    #[test]
    fn stream_alternates_labels() {
        let mut s = TraceStream::new(7, 1.0);
        let labels: Vec<u8> = (0..6).map(|_| s.next().unwrap().label).collect();
        assert_eq!(labels, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn difficulty_increases_noise() {
        // Higher difficulty -> higher sensor noise -> larger activation floor.
        let floor = |diff: f64| {
            let mut sum = 0.0;
            for i in 0..10 {
                let t = generate_trace(900 + i, false, diff);
                let acts = preprocess::preprocess(&t.samples);
                let mut v: Vec<u8> = acts.clone();
                v.sort_unstable();
                sum += v[v.len() / 4] as f64; // lower quartile ~ noise floor
            }
            sum / 10.0
        };
        assert!(floor(2.0) >= floor(0.1));
    }
}
