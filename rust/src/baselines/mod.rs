//! Comparison platforms of paper §V.
//!
//! The discussion compares the BSS-2 mobile system against:
//! * Intel Galileo (Azariadi et al.): 2.2 W, ~100 ms → 220 mJ/inference,
//! * Nvidia Jetson Nano (Seitanidis et al.): 5.0 W, ~1.48 ms → 7.4 mJ,
//! * a sub-V_t A-fib ASIC (Andersson et al.): 334 nW continuous, 94.9 %
//!   detection at 4.7 % false positives,
//! * plus our own float CPU reference (the "software solver" a user would
//!   deploy without the ASIC).
//!
//! Energies follow the paper's §V estimation method: published inference
//! runtimes × assumed platform power (footnote 4).

use crate::asic::consts as c;
use crate::nn::weights::TrainedModel;

/// A published comparison point.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub power_w: f64,
    pub time_per_inference_s: f64,
    pub note: &'static str,
}

impl Platform {
    pub fn energy_j(&self) -> f64 {
        self.power_w * self.time_per_inference_s
    }
}

/// The §V comparison set (paper-published numbers).
pub fn published() -> Vec<Platform> {
    vec![
        Platform {
            name: "Intel Galileo (Azariadi et al.)",
            power_w: 2.2,
            time_per_inference_s: 0.1,
            note: "220 mJ per inference (paper §V, footnote 4)",
        },
        Platform {
            name: "Nvidia Jetson Nano (Seitanidis et al.)",
            power_w: 5.0,
            time_per_inference_s: 7.4e-3 / 5.0,
            note: "7.4 mJ per inference (paper §V, footnote 4)",
        },
        Platform {
            name: "sub-Vt ASIC (Andersson et al.)",
            power_w: 334e-9,
            time_per_inference_s: 1.0, // real-time continuous classification
            note: "334 nW dedicated A-fib ASIC; 94.9 % det, 4.7 % FP",
        },
    ]
}

/// Float CPU reference: the same network in f32 on this host, timed for a
/// software-baseline energy estimate at a given platform power.
pub struct CpuFloatBaseline {
    pub model: TrainedModel,
}

impl CpuFloatBaseline {
    pub fn new(model: TrainedModel) -> CpuFloatBaseline {
        CpuFloatBaseline { model }
    }

    /// Float forward pass: the continuous relaxation of the hardware path
    /// (per-layer scales + ReLU + activation clipping applied in f32, but
    /// no ADC rounding, no noise, no fixed pattern).  This is the software
    /// solver a user would run from the same trained checkpoint.
    pub fn forward(&self, acts: &[f32]) -> [f32; 2] {
        assert_eq!(acts.len(), c::MODEL_IN);
        let mut x0 = vec![0.0f32; c::K_LOGICAL];
        x0[..c::MODEL_IN].copy_from_slice(acts);

        let dense = |x: &[f32], w: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; c::N_COLS];
            for (r, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &w[r * c::N_COLS..(r + 1) * c::N_COLS];
                for (o, &wv) in out.iter_mut().zip(row) {
                    *o += xv * wv;
                }
            }
            out
        };
        // Float analogue of the analog front-end + SIMD requantisation:
        // membrane/ADC saturation, then relu + >>RELU_SHIFT + 5-bit clip
        // (everything except rounding, noise and the fixed pattern — the
        // trained decision function *uses* the saturation).
        let adc = |v: f32| -> f32 {
            v.clamp(-c::MEMBRANE_CLIP, c::MEMBRANE_CLIP)
                .clamp(c::ADC_MIN as f32, c::ADC_MAX as f32)
        };
        let requant = |v: f32| -> f32 {
            (adc(v).max(0.0) / (1 << c::RELU_SHIFT) as f32)
                .min(c::X_MAX as f32)
        };
        let s = self.model.scales;

        let h1 = dense(&x0, &self.model.pass_weights[0]);
        let h1: Vec<f32> = h1.iter().map(|&v| requant(s[0] * v)).collect();

        let h2raw = dense(&h1, &self.model.pass_weights[1]);
        let mut h2 = vec![0.0f32; c::K_LOGICAL];
        for j in 0..c::FC1_OUT {
            // Saturation applies per physical column block before the
            // digital partial sum.
            h2[j] = requant(adc(s[1] * h2raw[j]) + adc(s[1] * h2raw[c::FC1_OUT + j]));
        }

        let h3: Vec<f32> = dense(&h2, &self.model.pass_weights[2])
            .iter()
            .map(|&v| adc(s[2] * v))
            .collect();
        let outs = &h3[2 * c::FC1_OUT..2 * c::FC1_OUT + c::FC2_OUT];
        let pool = |g: &[f32]| g.iter().sum::<f32>() / g.len() as f32;
        [
            pool(&outs[..c::POOL_GROUP]),
            pool(&outs[c::POOL_GROUP..]),
        ]
    }

    pub fn classify(&self, acts: &[f32]) -> u8 {
        let s = self.forward(acts);
        (s[1] > s[0]) as u8
    }
}

/// Comparison row: platform name, energy/inference, relative to BSS-2.
pub fn comparison_table(bss2_energy_j: f64) -> Vec<(String, f64, f64)> {
    let mut rows: Vec<(String, f64, f64)> = published()
        .iter()
        .map(|p| {
            (
                p.name.to_string(),
                p.energy_j(),
                p.energy_j() / bss2_energy_j,
            )
        })
        .collect();
    rows.insert(
        0,
        ("BSS-2 mobile system (this work)".into(), bss2_energy_j, 1.0),
    );
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mapping;

    #[test]
    fn published_energies_match_paper() {
        let p = published();
        assert!((p[0].energy_j() * 1e3 - 220.0).abs() < 1.0);
        assert!((p[1].energy_j() * 1e3 - 7.4).abs() < 0.1);
        assert!(p[2].power_w < 1e-6);
    }

    #[test]
    fn comparison_ratios() {
        // Paper: BSS-2 1.56 mJ vs 220 mJ vs 7.4 mJ -> ratios ~141x, ~4.7x.
        let rows = comparison_table(1.56e-3);
        assert_eq!(rows[0].2, 1.0);
        assert!((rows[1].2 - 141.0).abs() < 2.0, "galileo ratio {}", rows[1].2);
        assert!((rows[2].2 - 4.74).abs() < 0.1, "jetson ratio {}", rows[2].2);
    }

    fn tiny_model() -> TrainedModel {
        let wc = vec![1.0; c::CONV_CHANNELS * c::ECG_CHANNELS * c::CONV_KERNEL];
        let w1 = vec![1.0; c::K_LOGICAL * c::FC1_OUT];
        let w2 = vec![1.0; c::FC1_OUT * c::FC2_OUT];
        TrainedModel {
            pass_weights: [
                mapping::pack_conv(&wc),
                mapping::pack_fc1(&w1),
                mapping::pack_fc2(&w2),
            ],
            scales: [1.0, 1.0, 1.0],
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            noise_sigma: 0.0,
            train_metrics: Default::default(),
        }
    }

    #[test]
    fn cpu_baseline_runs() {
        let b = CpuFloatBaseline::new(tiny_model());
        let acts = vec![1.0f32; c::MODEL_IN];
        let s = b.forward(&acts);
        // All-ones weights: both pooled outputs equal and positive.
        assert!(s[0] > 0.0);
        assert!((s[0] - s[1]).abs() < 1e-3);
        assert_eq!(b.classify(&acts), 0); // ties break to class 0
    }

    #[test]
    fn cpu_baseline_zero_input() {
        let b = CpuFloatBaseline::new(tiny_model());
        let s = b.forward(&vec![0.0; c::MODEL_IN]);
        assert_eq!(s, [0.0, 0.0]);
    }
}
