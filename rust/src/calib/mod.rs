//! Calibration & drift-compensation subsystem: close the loop from
//! measurement to serving.
//!
//! The paper credits "calibration routines for the analog network core"
//! (Weis et al., arXiv:2006.13177) for making the ASIC usable outside the
//! lab; hxtorch exposes the same measured-deviation workflow to training
//! (Spilger et al., arXiv:2006.13138).  `asic::calib` holds the raw
//! measurement routines; this subsystem turns them into an operational
//! loop for a serving fleet of aging, heterogeneous chips:
//!
//! * [`drift`] — the physics: a seeded, chip-time-driven Ornstein–
//!   Uhlenbeck wander of per-column gain/offset plus a temperature
//!   coefficient, advanced deterministically in simulated µs as the
//!   engine serves (`asic::array` consults it at ADC conversion).
//! * [`profile`] — the artifact: a versioned per-chip [`CalibProfile`]
//!   (measured gain/offset, residual rms, chip-time stamp, reps),
//!   persisted through `runtime::artifacts` and *applied* as a
//!   [`ColumnCorrection`] in the post-ADC path of `coordinator::engine`
//!   and `nn::executor`, so MACs are compensated against the measured
//!   pattern rather than the ideal one.
//! * [`monitor`] — the symptom tracker: per-chip logit-margin EWMA vs its
//!   post-calibration baseline.
//! * [`scheduler`] — the policy: age- and margin-triggered
//!   [`RecalibPolicy`], owned by `fleet::pool`, which drains one replica
//!   into `ChipState::Calibrating` while the rest of the pool serves.
//!
//! `repro calibrate` drives a full-chip run from the CLI;
//! `benches/drift_recovery.rs` demonstrates accuracy recovery over a long
//! drifting run with the loop on vs off.

pub mod drift;
pub mod monitor;
pub mod profile;
pub mod scheduler;

pub use drift::{DriftParams, DriftState, DRIFT_TICK_US};
pub use monitor::{DriftMonitor, MarginSnapshot};
pub use profile::{
    substrate_hash, CalibProfile, ColumnCorrection, UnsupportedFormat,
    PROFILE_FORMAT,
};
pub use scheduler::{RecalibPolicy, RecalibReason};
