//! Drift monitor: per-chip tracking of prediction-margin degradation.
//!
//! Calibration *age* (chip time since the last profile) is the primary
//! recalibration trigger and lives in `fleet::health` as an atomic counter.
//! This module tracks the *symptom*: as the analog pattern wanders away
//! from the applied profile, class scores move toward each other and the
//! logit margin |s0 - s1| shrinks.  The monitor keeps an EWMA of the
//! margin, freezes a baseline over the first post-calibration window, and
//! reports the degradation ratio `ewma / baseline` the policy thresholds.
//!
//! Updated from the chip worker after every served batch, read from the
//! dispatch path — a `Mutex` over four floats, uncontended in practice.

use std::sync::Mutex;

/// Margin samples averaged into the post-calibration baseline before the
/// degradation ratio becomes meaningful.
pub const BASELINE_WARMUP: u64 = 32;

#[derive(Debug, Clone, Copy)]
struct MonitorInner {
    /// EWMA of the absolute logit margin [LSB].
    ewma: f64,
    /// Frozen mean margin of the first [`BASELINE_WARMUP`] samples.
    baseline: f64,
    /// Running sum while the baseline accumulates.
    warmup_sum: f64,
    /// Margin samples since the last (re)calibration.
    samples: u64,
}

/// Point-in-time monitor view.
#[derive(Debug, Clone, Copy)]
pub struct MarginSnapshot {
    pub ewma: f64,
    pub baseline: f64,
    pub samples: u64,
}

/// Per-chip margin tracker (see module docs).
pub struct DriftMonitor {
    alpha: f64,
    inner: Mutex<MonitorInner>,
}

impl DriftMonitor {
    /// `alpha` is the EWMA weight of one new sample (e.g. 1/64).
    pub fn new(alpha: f64) -> DriftMonitor {
        DriftMonitor {
            alpha: alpha.clamp(1e-6, 1.0),
            inner: Mutex::new(MonitorInner {
                ewma: 0.0,
                baseline: 0.0,
                warmup_sum: 0.0,
                samples: 0,
            }),
        }
    }

    /// Record one inference's class scores.
    pub fn record_scores(&self, scores: &[f32; 2]) {
        self.record_margin((scores[0] - scores[1]).abs() as f64);
    }

    pub fn record_margin(&self, margin: f64) {
        let mut g = self.inner.lock().unwrap();
        g.samples += 1;
        if g.samples <= BASELINE_WARMUP {
            g.warmup_sum += margin;
            g.baseline = g.warmup_sum / g.samples as f64;
            g.ewma = g.baseline;
        } else {
            g.ewma += self.alpha * (margin - g.ewma);
        }
    }

    /// Forget everything: called right after a recalibration so the next
    /// baseline reflects the freshly compensated chip.
    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        *g = MonitorInner { ewma: 0.0, baseline: 0.0, warmup_sum: 0.0, samples: 0 };
    }

    pub fn snapshot(&self) -> MarginSnapshot {
        let g = self.inner.lock().unwrap();
        MarginSnapshot { ewma: g.ewma, baseline: g.baseline, samples: g.samples }
    }

    /// `ewma / baseline`, or `None` until the baseline warmed up (or when
    /// the baseline margin is degenerate).
    pub fn degradation(&self) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        if g.samples <= BASELINE_WARMUP || g.baseline <= 1e-9 {
            return None;
        }
        Some(g.ewma / g.baseline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_degradation_ratio() {
        let m = DriftMonitor::new(0.25);
        for _ in 0..BASELINE_WARMUP {
            m.record_margin(40.0);
        }
        assert!(m.degradation().is_none(), "warmup not finished");
        m.record_margin(40.0);
        let d = m.degradation().unwrap();
        assert!((d - 1.0).abs() < 1e-9, "healthy chip ratio {d}");
        // Margins collapse: ratio decays toward 0.25 of baseline.
        for _ in 0..256 {
            m.record_margin(10.0);
        }
        let d = m.degradation().unwrap();
        assert!(d < 0.3, "degraded ratio {d}");
        let s = m.snapshot();
        assert!((s.baseline - 40.0).abs() < 1e-9);
        assert!(s.samples > BASELINE_WARMUP);
    }

    #[test]
    fn reset_clears_baseline() {
        let m = DriftMonitor::new(0.5);
        for _ in 0..=BASELINE_WARMUP {
            m.record_margin(20.0);
        }
        assert!(m.degradation().is_some());
        m.reset();
        assert!(m.degradation().is_none());
        assert_eq!(m.snapshot().samples, 0);
    }

    #[test]
    fn zero_baseline_never_divides() {
        let m = DriftMonitor::new(0.5);
        for _ in 0..=BASELINE_WARMUP {
            m.record_margin(0.0);
        }
        assert!(m.degradation().is_none(), "degenerate baseline guarded");
    }

    #[test]
    fn record_scores_uses_absolute_margin() {
        let m = DriftMonitor::new(0.5);
        m.record_scores(&[-10.0, 30.0]);
        assert!((m.snapshot().ewma - 40.0).abs() < 1e-6);
    }
}
