//! Recalibration policy: *when* a chip should leave the serving pool and
//! re-measure its profile.
//!
//! Two triggers, mirroring how the real system is operated:
//! * **age** — the profile's chip-time age exceeded `max_age_us`; drift
//!   has had time to wander regardless of what traffic observed; and
//! * **margin** — the observed logit-margin EWMA degraded below
//!   `margin_degrade_ratio` of its post-calibration baseline (symptom-
//!   driven, catches faster-than-expected drift).
//!
//! The policy *decides*; `fleet::pool` owns the act: it flips the chip to
//! `ChipState::Calibrating` (the scheduler stops admitting regular work),
//! lets the FIFO queue drain, runs the measurement on the worker, and
//! re-admits on success.  `min_serving` keeps the pool available — a
//! recalibration is deferred while it would leave fewer than that many
//! healthy replicas serving (so a single-chip fleet never self-drains
//! unless explicitly allowed).

/// Why a recalibration was triggered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecalibReason {
    /// The calibration profile exceeded its chip-time age budget.
    Aged,
    /// The logit margin degraded below the policy ratio.
    MarginDegraded,
}

impl RecalibReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            RecalibReason::Aged => "profile aged out",
            RecalibReason::MarginDegraded => "logit margin degraded",
        }
    }
}

/// Age- and symptom-triggered recalibration policy.
#[derive(Debug, Clone)]
pub struct RecalibPolicy {
    /// Recalibrate when the profile is older than this [µs of chip time].
    pub max_age_us: u64,
    /// Recalibrate when the margin EWMA falls below this fraction of the
    /// post-calibration baseline (0 disables the symptom trigger).
    pub margin_degrade_ratio: f64,
    /// Measurement repetitions per recalibration.
    pub reps: usize,
    /// Minimum healthy replicas that must keep serving while one chip
    /// calibrates.
    pub min_serving: usize,
}

impl Default for RecalibPolicy {
    fn default() -> RecalibPolicy {
        RecalibPolicy {
            // ~36k inferences at the paper's 276 µs — tight enough that
            // the default drift field stays well-compensated.
            max_age_us: 10_000_000,
            margin_degrade_ratio: 0.7,
            reps: 32,
            min_serving: 1,
        }
    }
}

impl RecalibPolicy {
    /// A policy that never fires (both triggers disabled).
    pub fn disabled() -> RecalibPolicy {
        RecalibPolicy {
            max_age_us: u64::MAX,
            margin_degrade_ratio: 0.0,
            ..Default::default()
        }
    }

    /// Should a chip with this profile age and margin degradation leave
    /// the pool to recalibrate?  `degradation` is `None` until the
    /// monitor's baseline warmed up.
    pub fn should_recalibrate(
        &self,
        age_us: u64,
        degradation: Option<f64>,
    ) -> Option<RecalibReason> {
        if age_us > self.max_age_us {
            return Some(RecalibReason::Aged);
        }
        if self.margin_degrade_ratio > 0.0 {
            if let Some(d) = degradation {
                if d < self.margin_degrade_ratio {
                    return Some(RecalibReason::MarginDegraded);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn age_trigger() {
        let p = RecalibPolicy { max_age_us: 1_000, ..Default::default() };
        assert_eq!(p.should_recalibrate(999, None), None);
        assert_eq!(p.should_recalibrate(1_000, None), None, "inclusive budget");
        assert_eq!(p.should_recalibrate(1_001, None), Some(RecalibReason::Aged));
    }

    #[test]
    fn margin_trigger_needs_warmed_monitor() {
        let p = RecalibPolicy {
            max_age_us: u64::MAX,
            margin_degrade_ratio: 0.7,
            ..Default::default()
        };
        assert_eq!(p.should_recalibrate(0, None), None);
        assert_eq!(p.should_recalibrate(0, Some(0.9)), None);
        assert_eq!(
            p.should_recalibrate(0, Some(0.5)),
            Some(RecalibReason::MarginDegraded)
        );
    }

    #[test]
    fn age_takes_precedence_over_margin() {
        let p = RecalibPolicy {
            max_age_us: 10,
            margin_degrade_ratio: 0.7,
            ..Default::default()
        };
        assert_eq!(
            p.should_recalibrate(11, Some(0.1)),
            Some(RecalibReason::Aged)
        );
    }

    #[test]
    fn disabled_policy_never_fires() {
        let p = RecalibPolicy::disabled();
        assert_eq!(p.should_recalibrate(u64::MAX - 1, Some(0.0)), None);
    }

    #[test]
    fn reasons_have_labels() {
        assert!(RecalibReason::Aged.as_str().contains("aged"));
        assert!(RecalibReason::MarginDegraded.as_str().contains("margin"));
    }
}
