//! Analog drift model: a chip-time-driven Ornstein–Uhlenbeck wander of the
//! per-column gain/offset fixed pattern, plus a slow temperature swing.
//!
//! The paper's claim that the mobile system operates "reliably outside a
//! specialized lab setting" rests on the calibration routines (Weis et al.,
//! arXiv:2006.13177) compensating not just the *static* fixed pattern but
//! its slow wander with supply temperature and device aging.  This module
//! supplies the physics those routines fight: each column's gain and offset
//! performs a mean-reverting random walk around its calibrated value, and a
//! deterministic sinusoidal temperature profile couples into both through
//! first-order temperature coefficients.
//!
//! Determinism: the OU process advances on a fixed [`DRIFT_TICK_US`] grid
//! of *simulated chip time*.  Ticks fire at absolute multiples of the
//! quantum, so advancing by 300 µs then 700 µs produces bit-identically the
//! same state as advancing by 1000 µs once — runs are reproducible no
//! matter how serving partitions chip time (property-tested below).

use crate::util::rng::SplitMix64;

/// Chip-time quantum of one OU update [µs].  One inference is ~276 µs, so
/// the wander is effectively frozen within a single batch and moves on the
/// serving/idle timescale — exactly the regime recalibration targets.
pub const DRIFT_TICK_US: u64 = 1_000;

/// Parameters of the per-column drift process.
#[derive(Debug, Clone, Copy)]
pub struct DriftParams {
    /// OU relaxation time [µs of chip time].
    pub tau_us: f64,
    /// Stationary std of the multiplicative gain wander (relative).
    pub sigma_gain: f64,
    /// Stationary std of the additive offset wander [ADC LSB].
    pub sigma_offset: f64,
    /// Amplitude of the deterministic temperature swing [K].
    pub temp_amplitude_k: f64,
    /// Period of the temperature swing [µs of chip time].
    pub temp_period_us: f64,
    /// Relative gain change per kelvin (all columns move together).
    pub temp_gain_per_k: f64,
    /// Offset change per kelvin [ADC LSB].
    pub temp_offset_per_k: f64,
}

impl Default for DriftParams {
    /// Timescales chosen so drift is visible over seconds of chip time
    /// (thousands of inferences) while one batch sees a frozen pattern.
    fn default() -> DriftParams {
        DriftParams {
            tau_us: 2.0e6,
            sigma_gain: 0.04,
            sigma_offset: 5.0,
            temp_amplitude_k: 3.0,
            temp_period_us: 3.0e6,
            temp_gain_per_k: 0.007,
            temp_offset_per_k: 0.8,
        }
    }
}

impl DriftParams {
    /// A drift field with the random wander disabled (temperature only) —
    /// useful for isolating the deterministic component in tests.
    pub fn temperature_only() -> DriftParams {
        DriftParams { sigma_gain: 0.0, sigma_offset: 0.0, ..Default::default() }
    }
}

/// Live drift state of one array half: the current wander realisation plus
/// the chip clock that drives it.
#[derive(Debug, Clone)]
pub struct DriftState {
    params: DriftParams,
    rng: SplitMix64,
    /// Absolute chip time [µs].
    time_us: u64,
    /// Chip time already consumed by OU ticks [µs].
    ticked_us: u64,
    /// Per-column multiplicative gain deviation (around 0).
    gain_wander: Vec<f32>,
    /// Per-column additive offset deviation [LSB].
    offset_wander: Vec<f32>,
}

impl DriftState {
    pub fn new(n: usize, seed: u64, params: DriftParams) -> DriftState {
        DriftState {
            params,
            rng: SplitMix64::new(seed),
            time_us: 0,
            ticked_us: 0,
            gain_wander: vec![0.0; n],
            offset_wander: vec![0.0; n],
        }
    }

    pub fn params(&self) -> &DriftParams {
        &self.params
    }

    /// Columns this field covers (must match the array half it drives).
    pub fn columns(&self) -> usize {
        self.gain_wander.len()
    }

    pub fn time_us(&self) -> u64 {
        self.time_us
    }

    /// Advance the chip clock by `us` simulated microseconds, applying one
    /// OU step per crossed [`DRIFT_TICK_US`] boundary.
    pub fn advance_us(&mut self, us: u64) {
        self.time_us += us;
        while self.time_us - self.ticked_us >= DRIFT_TICK_US {
            self.ticked_us += DRIFT_TICK_US;
            self.tick();
        }
    }

    /// One exact OU update over a tick: `x <- a x + sqrt(1-a^2) sigma g`.
    fn tick(&mut self) {
        // lint:allow(det-float-intrinsic: exact OU decay; libm exp fixed per build)
        let a = (-(DRIFT_TICK_US as f64) / self.params.tau_us).exp();
        let b = (1.0 - a * a).sqrt();
        let (sg, so) = (self.params.sigma_gain, self.params.sigma_offset);
        for g in self.gain_wander.iter_mut() {
            *g = (a * *g as f64 + b * sg * self.rng.gauss()) as f32;
        }
        for o in self.offset_wander.iter_mut() {
            *o = (a * *o as f64 + b * so * self.rng.gauss()) as f32;
        }
    }

    /// Deviation from the reference temperature at the current chip time.
    pub fn temp_delta_k(&self) -> f64 {
        if self.params.temp_period_us <= 0.0 {
            return 0.0;
        }
        let phase = self.time_us as f64 / self.params.temp_period_us;
        self.params.temp_amplitude_k
            // lint:allow(det-float-intrinsic: seeded temp model; libm sin fixed per build)
            * (2.0 * std::f64::consts::PI * phase).sin()
    }

    /// Multiplicative factor on column `col`'s calibrated gain.
    #[inline]
    pub fn gain_factor(&self, col: usize) -> f32 {
        (1.0 + self.gain_wander[col] as f64
            + self.params.temp_gain_per_k * self.temp_delta_k()) as f32
    }

    /// Additive shift on column `col`'s calibrated offset [LSB].
    #[inline]
    pub fn offset_delta(&self, col: usize) -> f32 {
        (self.offset_wander[col] as f64
            + self.params.temp_offset_per_k * self.temp_delta_k()) as f32
    }

    /// Root-mean-square of the current offset wander [LSB] (diagnostics).
    pub fn offset_wander_rms(&self) -> f32 {
        if self.offset_wander.is_empty() {
            return 0.0;
        }
        let ss: f64 = self
            .offset_wander
            .iter()
            .map(|&o| (o as f64) * (o as f64))
            .sum();
        (ss / self.offset_wander.len() as f64).sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drifty() -> DriftParams {
        DriftParams {
            tau_us: 50_000.0,
            sigma_gain: 0.05,
            sigma_offset: 6.0,
            ..Default::default()
        }
    }

    #[test]
    fn starts_at_identity() {
        let d = DriftState::new(8, 1, drifty());
        for col in 0..8 {
            assert_eq!(d.gain_factor(col), 1.0);
            assert_eq!(d.offset_delta(col), 0.0);
        }
    }

    #[test]
    fn advance_partition_independent() {
        // 300 + 700 µs must land bit-identically on 1000 µs, and a long
        // run chopped into odd pieces must equal one big advance.
        let mk = || DriftState::new(16, 42, drifty());
        let (mut a, mut b) = (mk(), mk());
        a.advance_us(300);
        a.advance_us(700);
        b.advance_us(1000);
        assert_eq!(a.gain_wander, b.gain_wander);
        assert_eq!(a.offset_wander, b.offset_wander);

        let (mut c, mut d) = (mk(), mk());
        let mut total = 0u64;
        for step in [137u64, 863, 1, 999, 2500, 12_345, 7] {
            c.advance_us(step);
            total += step;
        }
        d.advance_us(total);
        assert_eq!(c.gain_wander, d.gain_wander);
        assert_eq!(c.offset_wander, d.offset_wander);
        assert_eq!(c.time_us(), d.time_us());
    }

    #[test]
    fn wander_reaches_stationary_scale() {
        // After many relaxation times the wander std approaches sigma.
        let p = drifty();
        let mut d = DriftState::new(512, 7, p);
        d.advance_us(20 * p.tau_us as u64);
        let rms = d.offset_wander_rms() as f64;
        assert!(
            rms > 0.4 * p.sigma_offset && rms < 2.0 * p.sigma_offset,
            "offset wander rms {rms} vs sigma {}",
            p.sigma_offset
        );
    }

    #[test]
    fn mean_reversion_bounds_the_walk() {
        // Unlike a pure random walk, the OU wander must not grow without
        // bound: rms after 100 tau stays the same order as after 20 tau.
        let p = drifty();
        let mut d = DriftState::new(256, 9, p);
        d.advance_us(20 * p.tau_us as u64);
        let early = d.offset_wander_rms();
        d.advance_us(80 * p.tau_us as u64);
        let late = d.offset_wander_rms();
        assert!(late < 3.0 * early, "rms grew {early} -> {late}");
    }

    #[test]
    fn temperature_term_is_deterministic_and_periodic() {
        let p = DriftParams::temperature_only();
        let mut d = DriftState::new(4, 3, p);
        d.advance_us((p.temp_period_us / 4.0) as u64); // quarter period
        let quarter = d.temp_delta_k();
        assert!((quarter - p.temp_amplitude_k).abs() < 1e-6, "{quarter}");
        // All columns move together under temperature.
        assert_eq!(d.gain_factor(0), d.gain_factor(3));
        assert!(d.gain_factor(0) > 1.0);
        assert!(d.offset_delta(0) > 0.0);
        // Full period returns to (near) zero.
        let mut e = DriftState::new(4, 3, p);
        e.advance_us(p.temp_period_us as u64);
        assert!(e.temp_delta_k().abs() < 1e-6);
    }

    #[test]
    fn seeds_decorrelate_chips() {
        let mut a = DriftState::new(64, 1, drifty());
        let mut b = DriftState::new(64, 2, drifty());
        a.advance_us(100_000);
        b.advance_us(100_000);
        assert_ne!(a.offset_wander, b.offset_wander);
    }
}
