//! Per-chip calibration profile: the versioned artifact produced by a
//! full-chip calibration run and consumed by the serving path.
//!
//! The real workflow (Weis et al., arXiv:2006.13177; hxtorch, Spilger et
//! al., arXiv:2006.13138) measures each column's gain/offset against test
//! pulses and hands the *measured* deviation to the lowering path, so MACs
//! are compensated against the chip that actually executes them rather
//! than an ideal substrate.  [`CalibProfile`] is that measurement as a
//! persistable artifact: per-half gain/offset vectors, the residual rms of
//! the fit, the chip-time stamp of the measurement (so its *age* is
//! well-defined under drift), and the repetition count that sets the
//! measurement noise floor.
//!
//! [`ColumnCorrection`] is the serving-side application: the inverse map
//! `adc -> round((adc - offset) / gain)` applied right after ADC readout,
//! which is where the SIMD CPUs of the real system apply it.

use std::path::Path;

use crate::asic::array::{round_half_even, AnalogArray};
use crate::asic::calib::calibrate_half_with;
use crate::asic::consts as c;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// Artifact format tag (bump on layout changes).  v2 added the mandatory
/// `substrate` identity hash.
pub const PROFILE_FORMAT: &str = "bss2-calib-v2";

/// [`CalibProfile::parse`] error for a well-formed artifact of a
/// *different* format version.  Distinguished from corruption so loaders
/// can treat a leftover older-version profile like any other
/// inapplicable profile (skip and re-measure) instead of refusing to
/// start, while still failing loudly on genuinely corrupt artifacts.
#[derive(Debug)]
pub struct UnsupportedFormat(pub String);

impl std::fmt::Display for UnsupportedFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unsupported calib profile format `{}` (expected {})",
            self.0, PROFILE_FORMAT
        )
    }
}

impl std::error::Error for UnsupportedFormat {}

/// Columns with a measured gain below this are treated as dead and left
/// uncorrected (inverting a near-zero gain would amplify noise unboundedly).
pub const MIN_CORRECTABLE_GAIN: f32 = 0.05;

/// Identity of a native substrate: an FNV-1a hash over the un-drifted
/// base calibration pattern (gain/offset bit patterns of both halves).
/// The base pattern is fixed for the lifetime of a chip — drift wanders
/// *around* it — so the hash names the silicon, not its current state.
/// A profile is only meaningful on the silicon it was measured on:
/// applying an inverse gain/offset measured elsewhere corrupts
/// inferences instead of compensating them, so `Engine::apply_profile`
/// verifies this hash before accepting a profile.
pub fn substrate_hash(halves: &[AnalogArray; 2]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |bits: u32| {
        h ^= bits as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for half in halves {
        for &g in &half.calib.gain {
            mix(g.to_bits());
        }
        for &o in &half.calib.offset {
            mix(o.to_bits());
        }
    }
    h
}

/// A versioned per-chip calibration measurement.
#[derive(Debug, Clone)]
pub struct CalibProfile {
    /// Fleet ordinal of the chip the profile was measured on.
    pub chip: usize,
    /// [`substrate_hash`] of the silicon the measurement ran on.
    pub substrate: u64,
    /// Chip-time stamp of the measurement [µs] (drift age reference).
    pub chip_time_us: u64,
    /// Measurement repetitions (noise suppressed by sqrt(reps)).
    pub reps: usize,
    /// Measured per-half, per-column gain.
    pub gain: [Vec<f32>; 2],
    /// Measured per-half, per-column offset [LSB].
    pub offset: [Vec<f32>; 2],
    /// Per-half residual rms of the two-point fit [LSB].
    pub residual_rms: [f32; 2],
}

impl CalibProfile {
    /// The ideal-substrate profile (gain 1, offset 0) — applying it is a
    /// no-op correction.  Its substrate hash is 0, which no measurable
    /// substrate produces, so it never passes the apply-time check.
    pub fn nominal(chip: usize) -> CalibProfile {
        CalibProfile {
            chip,
            substrate: 0,
            chip_time_us: 0,
            reps: 0,
            gain: [vec![1.0; c::N_COLS], vec![1.0; c::N_COLS]],
            offset: [vec![0.0; c::N_COLS], vec![0.0; c::N_COLS]],
            residual_rms: [0.0, 0.0],
        }
    }

    /// Full-chip calibration: measure both array halves with
    /// [`calibrate_half_with`] (which saves, swaps in the diagnostic
    /// pattern, and restores the serving weights — safe mid-serving).
    /// The measurement sees the *current* effective pattern, drift
    /// included, which is exactly what makes recalibration work.
    pub fn measure(
        halves: &mut [AnalogArray; 2],
        rng: &mut SplitMix64,
        reps: usize,
        noise_sigma: f64,
        chip: usize,
        chip_time_us: u64,
    ) -> CalibProfile {
        let reps = reps.max(1);
        let m0 = calibrate_half_with(&mut halves[0], rng, reps, noise_sigma);
        let m1 = calibrate_half_with(&mut halves[1], rng, reps, noise_sigma);
        CalibProfile {
            chip,
            substrate: substrate_hash(halves),
            chip_time_us,
            reps,
            gain: [m0.gain_est, m1.gain_est],
            offset: [m0.offset_est, m1.offset_est],
            residual_rms: [m0.residual_rms, m1.residual_rms],
        }
    }

    /// Chip time one full-chip measurement occupies [µs]: per half, `reps`
    /// offset integrations plus `2*reps` two-point gain integrations, plus
    /// the diagnostic-pattern write and the serving-weight restore.
    pub fn measurement_cost_us(reps: usize) -> f64 {
        let per_half = 3.0 * reps as f64 * c::INTEGRATION_CYCLE_US
            + 2.0 * c::WEIGHT_WRITE_US;
        2.0 * per_half
    }

    /// The serving-side correction for one half.
    pub fn correction(&self, half: usize) -> ColumnCorrection {
        ColumnCorrection::from_measured(&self.gain[half], &self.offset[half])
    }

    /// Worst per-half fit residual [LSB] (the health figure `fleet_stats`
    /// reports).
    pub fn worst_residual(&self) -> f32 {
        self.residual_rms[0].max(self.residual_rms[1])
    }

    // --- artifact (de)serialisation ---------------------------------------

    pub fn to_json(&self) -> String {
        let vec_f32 = |v: &[f32]| {
            Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
        };
        let mut m = std::collections::BTreeMap::new();
        m.insert("format".into(), Json::Str(PROFILE_FORMAT.into()));
        m.insert("chip".into(), Json::Num(self.chip as f64));
        // Hex string, not a number: a u64 hash does not survive the f64
        // round-trip a JSON number would impose.
        m.insert(
            "substrate".into(),
            Json::Str(format!("{:016x}", self.substrate)),
        );
        m.insert("chip_time_us".into(), Json::Num(self.chip_time_us as f64));
        m.insert("reps".into(), Json::Num(self.reps as f64));
        m.insert(
            "residual_rms".into(),
            Json::Arr(vec![
                Json::Num(self.residual_rms[0] as f64),
                Json::Num(self.residual_rms[1] as f64),
            ]),
        );
        m.insert(
            "gain".into(),
            Json::Arr(vec![vec_f32(&self.gain[0]), vec_f32(&self.gain[1])]),
        );
        m.insert(
            "offset".into(),
            Json::Arr(vec![vec_f32(&self.offset[0]), vec_f32(&self.offset[1])]),
        );
        Json::Obj(m).to_string()
    }

    pub fn parse(text: &str) -> anyhow::Result<CalibProfile> {
        let j = Json::parse(text)
            .map_err(|e| anyhow::anyhow!("calib profile: {e}"))?;
        // Only a well-formed *string* tag can name another version; a
        // wrong-typed `format` is corruption and fails loudly like
        // every other wrong-typed field.
        let format = j
            .req("format")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("format must be a string"))?;
        if format != PROFILE_FORMAT {
            return Err(UnsupportedFormat(format.into()).into());
        }
        let pair = |key: &str| -> anyhow::Result<[Vec<f32>; 2]> {
            let arr = j
                .req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not an array"))?;
            anyhow::ensure!(arr.len() == 2, "{key} needs 2 halves");
            let a = arr[0].to_f32_vec()?;
            let b = arr[1].to_f32_vec()?;
            anyhow::ensure!(
                a.len() == c::N_COLS && b.len() == c::N_COLS,
                "{key} halves must hold {} columns",
                c::N_COLS
            );
            Ok([a, b])
        };
        let gain = pair("gain")?;
        let offset = pair("offset")?;
        let resid = j.req("residual_rms")?.to_f32_vec()?;
        anyhow::ensure!(resid.len() == 2, "residual_rms needs 2 halves");
        // A wrong-typed scalar is a corrupt artifact and must fail
        // loudly, exactly like the gain/offset shape checks above — a
        // silent zero default would load as a chip-0, age-zero profile.
        let uint = |key: &str| -> anyhow::Result<u64> {
            j.req(key)?.as_uint().ok_or_else(|| {
                anyhow::anyhow!("{key} must be a non-negative integer")
            })
        };
        let substrate = j
            .req("substrate")?
            .as_str()
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| {
                anyhow::anyhow!("substrate must be a hex identity string")
            })?;
        Ok(CalibProfile {
            chip: uint("chip")? as usize,
            substrate,
            chip_time_us: uint("chip_time_us")?,
            reps: uint("reps")? as usize,
            gain,
            offset,
            residual_rms: [resid[0], resid[1]],
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> anyhow::Result<CalibProfile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }
}

/// Digital post-ADC correction for one array half: undo the measured
/// per-column gain/offset so downstream layers see the ideal substrate.
#[derive(Debug, Clone)]
pub struct ColumnCorrection {
    inv_gain: Vec<f32>,
    offset: Vec<f32>,
}

impl ColumnCorrection {
    /// No-op correction over `n` columns.
    pub fn identity(n: usize) -> ColumnCorrection {
        ColumnCorrection { inv_gain: vec![1.0; n], offset: vec![0.0; n] }
    }

    /// Correction from measured gain/offset vectors.  Columns whose gain
    /// fell below [`MIN_CORRECTABLE_GAIN`] are left unscaled (dead-column
    /// guard).
    pub fn from_measured(gain: &[f32], offset: &[f32]) -> ColumnCorrection {
        assert_eq!(gain.len(), offset.len());
        ColumnCorrection {
            inv_gain: gain
                .iter()
                .map(|&g| if g < MIN_CORRECTABLE_GAIN { 1.0 } else { 1.0 / g })
                .collect(),
            offset: offset.to_vec(),
        }
    }

    pub fn len(&self) -> usize {
        self.inv_gain.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inv_gain.is_empty()
    }

    #[inline]
    fn corrected(&self, col: usize, adc: f32) -> f32 {
        let v = (adc - self.offset[col]) * self.inv_gain[col];
        round_half_even(v).clamp(c::ADC_MIN as f32, c::ADC_MAX as f32)
    }

    /// Correct ADC counts in place (engine latch width).  `adc` may cover
    /// a prefix of the columns (partitioned tiles start at column 0).
    pub fn apply_i32(&self, adc: &mut [i32]) {
        assert!(adc.len() <= self.inv_gain.len());
        for (col, v) in adc.iter_mut().enumerate() {
            *v = self.corrected(col, *v as f32) as i32;
        }
    }

    /// Correct ADC counts in place (executor tile width).
    pub fn apply_i16(&self, adc: &mut [i16]) {
        assert!(adc.len() <= self.inv_gain.len());
        for (col, v) in adc.iter_mut().enumerate() {
            *v = self.corrected(col, *v as f32) as i16;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::array::ColumnCalib;

    fn fpn_halves(seed: u64) -> [AnalogArray; 2] {
        let mut rng = SplitMix64::new(seed);
        let mk = |rng: &mut SplitMix64| {
            let calib = ColumnCalib::fixed_pattern(c::N_COLS, rng);
            let mut a = AnalogArray::new(c::K_LOGICAL, c::N_COLS, calib);
            a.load_weights(&vec![17i8; c::K_LOGICAL * c::N_COLS]);
            a
        };
        [mk(&mut rng), mk(&mut rng)]
    }

    #[test]
    fn measure_recovers_fixed_pattern_and_keeps_weights() {
        let mut halves = fpn_halves(5);
        let before: [Vec<i8>; 2] =
            [halves[0].weights.clone(), halves[1].weights.clone()];
        let mut rng = SplitMix64::new(77);
        let p = CalibProfile::measure(&mut halves, &mut rng, 64, 2.0, 3, 123);
        assert_eq!(p.chip, 3);
        assert_eq!(p.chip_time_us, 123);
        for h in 0..2 {
            assert_eq!(halves[h].weights, before[h], "weights restored");
            let mut worst = 0.0f32;
            for (e, t) in p.gain[h].iter().zip(&halves[h].calib.gain) {
                worst = worst.max((e - t).abs() / t);
            }
            assert!(worst < 0.06, "half {h} worst gain error {worst}");
            assert!(p.residual_rms[h] < 2.0, "half {h} residual");
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut halves = fpn_halves(9);
        let mut rng = SplitMix64::new(1);
        let p = CalibProfile::measure(&mut halves, &mut rng, 8, 2.0, 1, 999);
        let q = CalibProfile::parse(&p.to_json()).unwrap();
        assert_eq!(q.chip, p.chip);
        assert_eq!(q.substrate, p.substrate, "identity hash must roundtrip");
        assert_eq!(q.chip_time_us, p.chip_time_us);
        assert_eq!(q.reps, p.reps);
        assert_eq!(q.gain, p.gain, "gain must roundtrip bit-exactly");
        assert_eq!(q.offset, p.offset);
        assert_eq!(q.residual_rms, p.residual_rms);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = CalibProfile::nominal(2);
        let path = std::env::temp_dir().join("bss2_calib_profile_test.json");
        p.save(&path).unwrap();
        let q = CalibProfile::load(&path).unwrap();
        assert_eq!(q.chip, 2);
        assert_eq!(q.gain[0], p.gain[0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn parse_rejects_bad_format_and_shape() {
        let p = CalibProfile::nominal(0);
        // A different format version is a *typed* error, so loaders can
        // skip stale artifacts without excusing corrupt ones.
        let stale = p.to_json().replace(PROFILE_FORMAT, "bss2-calib-v1");
        let err = CalibProfile::parse(&stale).unwrap_err();
        assert!(err.downcast_ref::<UnsupportedFormat>().is_some(), "{err}");
        let err = CalibProfile::parse("{}").unwrap_err();
        assert!(err.downcast_ref::<UnsupportedFormat>().is_none(), "{err}");
        // A wrong-typed tag is corruption, not another version.
        let mut j = Json::parse(&p.to_json()).unwrap();
        if let Json::Obj(m) = &mut j {
            m.insert("format".into(), Json::Num(42.0));
        }
        let err = CalibProfile::parse(&j.to_string()).unwrap_err();
        assert!(err.downcast_ref::<UnsupportedFormat>().is_none(), "{err}");
    }

    #[test]
    fn parse_rejects_wrong_typed_scalars() {
        let p = CalibProfile::nominal(1);
        for key in ["chip", "chip_time_us", "reps", "substrate"] {
            let mut j = Json::parse(&p.to_json()).unwrap();
            if let Json::Obj(m) = &mut j {
                m.insert(key.into(), Json::Str("not-a-count".into()));
            }
            let err = CalibProfile::parse(&j.to_string());
            assert!(err.is_err(), "wrong-typed `{key}` must fail loudly");
        }
    }

    #[test]
    fn substrate_hash_names_the_silicon() {
        assert_eq!(
            substrate_hash(&fpn_halves(5)),
            substrate_hash(&fpn_halves(5)),
            "same base pattern, same identity"
        );
        assert_ne!(
            substrate_hash(&fpn_halves(5)),
            substrate_hash(&fpn_halves(6)),
            "different silicon, different identity"
        );
        // Drift wanders around the base pattern without renaming it.
        let mut drifted = fpn_halves(5);
        for half in drifted.iter_mut() {
            half.set_drift(crate::calib::drift::DriftState::new(
                c::N_COLS,
                42,
                crate::calib::drift::DriftParams::default(),
            ));
            half.advance_us(500_000);
        }
        assert_eq!(substrate_hash(&fpn_halves(5)), substrate_hash(&drifted));
    }

    #[test]
    fn correction_inverts_gain_offset() {
        let corr = ColumnCorrection::from_measured(&[2.0, 0.5], &[10.0, -4.0]);
        // adc = gain * ideal + offset; correction recovers ideal.
        let mut adc = vec![(2.0f32 * 30.0 + 10.0) as i32, (0.5f32 * 40.0 - 4.0) as i32];
        corr.apply_i32(&mut adc);
        assert_eq!(adc, vec![30, 40]);
        let mut adc16 = vec![70i16, 16];
        corr.apply_i16(&mut adc16);
        assert_eq!(adc16, vec![30, 40]);
    }

    #[test]
    fn correction_guards_dead_columns_and_clips() {
        let corr = ColumnCorrection::from_measured(&[0.01, 1.0], &[0.0, -300.0]);
        let mut adc = vec![50i32, 0];
        corr.apply_i32(&mut adc);
        assert_eq!(adc[0], 50, "dead column left unscaled");
        assert_eq!(adc[1], c::ADC_MAX, "correction clips to ADC range");
    }

    #[test]
    fn nominal_correction_is_identity() {
        let p = CalibProfile::nominal(0);
        let corr = p.correction(0);
        let mut adc = vec![-5i32, 0, 17, 127];
        corr.apply_i32(&mut adc);
        assert_eq!(adc, vec![-5, 0, 17, 127]);
        assert_eq!(corr.len(), c::N_COLS);
        assert!(!corr.is_empty());
    }

    #[test]
    fn measurement_cost_scales_with_reps() {
        let c1 = CalibProfile::measurement_cost_us(16);
        let c2 = CalibProfile::measurement_cost_us(64);
        assert!(c2 > c1);
        // 2 halves x (3*64 integrations * 5 µs + 2 writes * 40 µs).
        assert!((c2 - 2.0 * (3.0 * 64.0 * 5.0 + 80.0)).abs() < 1e-9);
    }
}
