//! # bss2-mobile — BrainScaleS-2 mobile system, reproduced in software
//!
//! Reproduction of *"Demonstrating Analog Inference on the BrainScaleS-2
//! Mobile System"* (IEEE OJCAS 2022) as a three-layer Rust + JAX + Pallas
//! stack.  The physical system (mixed-signal ASIC + FPGA controller) is
//! replaced by faithful behavioural models; the analog vector-matrix
//! multiplication executes as an AOT-compiled Pallas kernel via PJRT.
//! See DESIGN.md for the substitution table and architecture.
//!
//! Module map:
//! * [`asic`] — the BSS-2 ASIC model (analog arrays, router, SIMD CPUs).
//! * [`calib`] — calibration & drift compensation: per-chip profiles, the
//!   analog drift model, and the fleet recalibration policy.
//! * [`fpga`] — the system-controller fabric (DMA, preprocessing, buffers).
//! * [`power`] — supply rails, INA219 sensors, energy model (Table 1).
//! * [`runtime`] — PJRT client: loads and executes `artifacts/*.hlo.txt`.
//! * [`nn`] — weights, logical->physical mapping, graph + partitioner.
//! * [`coordinator`] — standalone inference engine, batch runner, service.
//! * [`fleet`] — multi-chip scheduler: N engine replicas behind one
//!   least-loaded dispatcher with health tracking, backpressure, and
//!   transparent failover of failed jobs onto healthy replicas.
//! * [`fault`] — deterministic fault injection: seeded, chip-time-driven
//!   schedules of hardware faults (dead columns, ADC saturation, link
//!   corruption, frame drops, latency spikes, chip death) armed on the
//!   simulated hardware for chaos/soak testing (`repro chaos`).
//! * [`obs`] — fleet-wide observability: unified metrics registry,
//!   stage-level request tracing (host-ns + simulated chip-time), and
//!   the bounded structured event journal behind the `metrics`/`trace`/
//!   `journal` wire commands and `repro bench`.
//! * [`ecg`] — synthetic ECG: windowed generator, continuous
//!   episode-labeled stream source, binary dataset reader.
//! * [`train`] — hardware-in-the-loop training: mini-batch loop over the
//!   simulated substrate, straight-through estimator across quantisation
//!   and ADC saturation, f32 shadow weights, versioned `bss2-model-v1`
//!   artifacts (`repro train`).
//! * [`baselines`] — comparison platforms of paper §V.
//! * [`util`] — hand-rolled substrate (JSON, PRNG, CLI, bench, propcheck).

pub mod asic;
pub mod baselines;
pub mod calib;
pub mod coordinator;
pub mod ecg;
pub mod fault;
pub mod fleet;
pub mod fpga;
pub mod nn;
pub mod obs;
pub mod power;
pub mod runtime;
pub mod train;
pub mod util;
