//! Artifact directory layout + manifest.
//!
//! `make artifacts` produces (python build path, never re-run at runtime):
//! ```text
//! artifacts/
//!   vmm.hlo.txt        single-pass synapse-array executable
//!   model.hlo.txt      fused full network (weights baked in)
//!   weights.json       6-bit weights + calibration + per-layer scales
//!   manifest.json      hardware constants + artifact hashes
//!   vmm_testvec.json   kernel-level golden vectors
//!   model_testvec.json network-level golden vectors
//!   ecg_test.bin       500-trace held-out test set
//!   ecg_cal.bin        small calibration set
//!   fig8_training.csv  training metrics (paper Fig 8)
//! ```

use std::path::{Path, PathBuf};

use crate::asic::consts as c;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactDir {
    pub root: PathBuf,
}

impl ArtifactDir {
    pub fn new<P: Into<PathBuf>>(root: P) -> ArtifactDir {
        ArtifactDir { root: root.into() }
    }

    /// Default location: `$BSS2_ARTIFACTS` or `./artifacts`.
    pub fn default_location() -> ArtifactDir {
        let root = std::env::var("BSS2_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        ArtifactDir::new(root)
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.root.join(name)
    }

    pub fn vmm_hlo(&self) -> PathBuf {
        self.path("vmm.hlo.txt")
    }

    pub fn model_hlo(&self) -> PathBuf {
        self.path("model.hlo.txt")
    }

    pub fn weights(&self) -> PathBuf {
        self.path("weights.json")
    }

    pub fn manifest(&self) -> PathBuf {
        self.path("manifest.json")
    }

    pub fn ecg_test(&self) -> PathBuf {
        self.path("ecg_test.bin")
    }

    /// Per-chip calibration profile (`repro calibrate`, fleet
    /// recalibration): measured gain/offset + residual + chip-time stamp.
    pub fn calib_profile(&self, chip: usize) -> PathBuf {
        self.path(&format!("calib_chip{chip}.json"))
    }

    /// In-the-loop trained model artifact (`repro train` output,
    /// `bss2-model-v1`): weights + substrate stamp + training config.
    pub fn trained_model(&self) -> PathBuf {
        self.path("model_trained.json")
    }

    pub fn exists(&self) -> bool {
        self.manifest().exists() && self.vmm_hlo().exists()
    }

    pub fn require(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.exists(),
            "artifacts not found under {} — run `make artifacts` first",
            self.root.display()
        );
        Ok(())
    }

    pub fn load_manifest(&self) -> anyhow::Result<Manifest> {
        Manifest::load(&self.manifest())
    }
}

/// Parsed `manifest.json` (subset the runtime needs).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub scales: Vec<f64>,
    pub k_logical: usize,
    pub n_cols: usize,
    pub macs_total: usize,
    pub ops_total: usize,
    pub noise_sigma: f64,
    pub metrics: std::collections::BTreeMap<String, f64>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let hw = j.req("hw")?;
        let scales = j
            .req("scales")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("scales not an array"))?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0))
            .collect();
        let mut metrics = std::collections::BTreeMap::new();
        if let Some(m) = j.get("metrics").and_then(|m| m.as_obj()) {
            for (k, v) in m {
                if let Some(x) = v.as_f64() {
                    metrics.insert(k.clone(), x);
                }
            }
        }
        let man = Manifest {
            scales,
            k_logical: hw.req("k_logical")?.as_usize().unwrap_or(0),
            n_cols: hw.req("n_cols")?.as_usize().unwrap_or(0),
            macs_total: hw
                .req("macs")?
                .req("total")?
                .as_usize()
                .unwrap_or(0),
            ops_total: hw.req("ops_total")?.as_usize().unwrap_or(0),
            noise_sigma: hw.req("noise_sigma")?.as_f64().unwrap_or(0.0),
            metrics,
        };
        man.validate()?;
        Ok(man)
    }

    /// Cross-check the python-side constants against `asic::consts` — the
    /// two mirrors must never drift.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.k_logical == c::K_LOGICAL,
            "manifest k_logical {} != {}",
            self.k_logical,
            c::K_LOGICAL
        );
        anyhow::ensure!(
            self.n_cols == c::N_COLS,
            "manifest n_cols {} != {}",
            self.n_cols,
            c::N_COLS
        );
        anyhow::ensure!(
            self.macs_total == c::MACS_TOTAL,
            "manifest macs {} != {}",
            self.macs_total,
            c::MACS_TOTAL
        );
        anyhow::ensure!(self.scales.len() == 3, "expected 3 layer scales");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paths() {
        let d = ArtifactDir::new("/tmp/x");
        assert_eq!(d.vmm_hlo(), PathBuf::from("/tmp/x/vmm.hlo.txt"));
        assert_eq!(d.weights(), PathBuf::from("/tmp/x/weights.json"));
        assert_eq!(
            d.calib_profile(3),
            PathBuf::from("/tmp/x/calib_chip3.json")
        );
        assert_eq!(
            d.trained_model(),
            PathBuf::from("/tmp/x/model_trained.json")
        );
    }

    #[test]
    fn missing_dir_reports_error() {
        let d = ArtifactDir::new("/definitely/not/here");
        assert!(!d.exists());
        let err = d.require().unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_validation_catches_drift() {
        let m = Manifest {
            scales: vec![0.1, 0.2, 0.3],
            k_logical: c::K_LOGICAL,
            n_cols: c::N_COLS,
            macs_total: c::MACS_TOTAL,
            ops_total: c::OPS_TOTAL,
            noise_sigma: 2.0,
            metrics: Default::default(),
        };
        assert!(m.validate().is_ok());
        let bad = Manifest { k_logical: 99, ..m };
        assert!(bad.validate().is_err());
    }
}
