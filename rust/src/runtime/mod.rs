//! PJRT runtime: load + execute the AOT artifacts from the request path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (see DESIGN.md §2 and
//! /opt/xla-example/README.md).  Python never runs here.
//!
//! * [`artifacts`] — artifact directory layout + manifest parsing.
//! * [`client`] — compiled-executable cache and typed call helpers.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactDir, Manifest};
pub use client::{Runtime, VmmExecutable};
