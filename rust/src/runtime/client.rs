//! PJRT client wrapper: compiled-executable cache + typed call helpers for
//! the two artifact entry points.
//!
//! The VMM path is the inference hot loop, so weights/calibration literals
//! are staged once as device buffers (`buffer_from_host_literal`) and reused
//! across passes with `execute_b`; only the per-pass activation and noise
//! vectors are re-uploaded (they change every integration cycle, exactly
//! like events and physics on the real chip).

use std::path::Path;

use anyhow::Context;

use crate::asic::consts as c;

/// Typed staging failure: the device-buffer count did not match the
/// executable's operand layout.  Returned (never panicked) so a runtime
/// mismatch degrades into an error the engine/fleet can report.
#[derive(Debug, thiserror::Error)]
#[error("staging produced {got} device buffers, expected {expected}")]
pub struct WrongBufferCount {
    pub expected: usize,
    pub got: usize,
}

/// A PJRT CPU client plus compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

thread_local! {
    /// One PJRT CPU client per thread: multiple live clients in one
    /// process confuse the TFRT CPU backend's buffer bookkeeping
    /// (observed as `literal.size_bytes() == b->size()` check failures),
    /// and `PjRtClient` is `Rc`-based (not `Send`) anyway.
    static CPU_CLIENT: std::cell::RefCell<Option<xla::PjRtClient>> =
        const { std::cell::RefCell::new(None) };
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = CPU_CLIENT.with(|slot| -> anyhow::Result<xla::PjRtClient> {
            let mut slot = slot.borrow_mut();
            if let Some(c) = slot.as_ref() {
                return Ok(c.clone());
            }
            let c = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
            *slot = Some(c.clone());
            Ok(c)
        })?;
        Ok(Runtime { client })
    }

    pub fn compile_hlo_text(
        &self,
        path: &Path,
    ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))
    }

    /// Load the single-pass VMM executable.
    pub fn load_vmm(&self, path: &Path) -> anyhow::Result<VmmExecutable> {
        Ok(VmmExecutable { exe: self.compile_hlo_text(path)? })
    }

    /// Load the fused full-network executable.
    pub fn load_model(&self, path: &Path) -> anyhow::Result<ModelExecutable> {
        Ok(ModelExecutable::new(self.compile_hlo_text(path)?))
    }
}

/// `(x[256], w[256,256], gain[256], offset[256], noise[256], scale[])
///  -> (adc[256],)` — one physical integration cycle.
pub struct VmmExecutable {
    exe: xla::PjRtLoadedExecutable,
}

/// Weights + calibration staged on-device for one array pass.
///
/// PJRT's `BufferFromHostLiteral` copies *asynchronously*: the host literal
/// must stay alive until the copy completes, so the source literals are
/// retained alongside the device buffers (`_keep`).
pub struct StagedPass {
    w: xla::PjRtBuffer,
    gain: xla::PjRtBuffer,
    offset: xla::PjRtBuffer,
    scale: xla::PjRtBuffer,
    _keep: Vec<xla::Literal>,
}

impl VmmExecutable {
    fn lit_vec(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow::anyhow!("literal reshape: {e}"))
    }

    /// Stage a pass's static operands as device buffers (done once at
    /// engine construction — the "synapse matrix is filled with weight
    /// data" step of the paper's dataflow).
    pub fn stage_pass(
        &self,
        w: &[f32],
        gain: &[f32],
        offset: &[f32],
        scale: f32,
    ) -> anyhow::Result<StagedPass> {
        anyhow::ensure!(w.len() == c::K_LOGICAL * c::N_COLS, "weight shape");
        anyhow::ensure!(gain.len() == c::N_COLS && offset.len() == c::N_COLS);
        let client = self.exe.client();
        let lits = vec![
            Self::lit_vec(w, &[c::K_LOGICAL as i64, c::N_COLS as i64])?,
            Self::lit_vec(gain, &[c::N_COLS as i64])?,
            Self::lit_vec(offset, &[c::N_COLS as i64])?,
            xla::Literal::scalar(scale),
        ];
        let mut bufs = Vec::with_capacity(lits.len());
        for lit in &lits {
            bufs.push(
                client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow::anyhow!("stage buffer: {e}"))?,
            );
        }
        let got = bufs.len();
        if got != 4 {
            return Err(WrongBufferCount { expected: 4, got }.into());
        }
        let mut it = bufs.into_iter();
        match (it.next(), it.next(), it.next(), it.next()) {
            (Some(w), Some(gain), Some(offset), Some(scale)) => Ok(StagedPass {
                w,
                gain,
                offset,
                scale,
                _keep: lits,
            }),
            _ => Err(WrongBufferCount { expected: 4, got }.into()),
        }
    }

    /// One integration cycle against staged weights.  `x` are 5-bit
    /// activations (as f32), `noise` the temporal-noise realisation.
    pub fn run_pass(
        &self,
        staged: &StagedPass,
        x: &[f32],
        noise: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(x.len() == c::K_LOGICAL, "x length {}", x.len());
        anyhow::ensure!(noise.len() == c::N_COLS, "noise length");
        let client = self.exe.client();
        // Keep the host literals alive until the result sync (async copy).
        let x_lit = Self::lit_vec(x, &[c::K_LOGICAL as i64])?;
        let n_lit = Self::lit_vec(noise, &[c::N_COLS as i64])?;
        let xb = client
            .buffer_from_host_literal(None, &x_lit)
            .map_err(|e| anyhow::anyhow!("stage input: {e}"))?;
        let nb = client
            .buffer_from_host_literal(None, &n_lit)
            .map_err(|e| anyhow::anyhow!("stage input: {e}"))?;
        let args = [&xb, &staged.w, &staged.gain, &staged.offset, &nb, &staged.scale];
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("vmm execute: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e}"))
            .context("vmm output")
    }

    /// Batched integration: every activation vector in `xs` runs against
    /// the *same* staged pass — the PJRT twin of
    /// `nn::executor::PassRunner::run_tile_batch`.  Weights/calibration
    /// are device-resident (`StagedPass`), so the per-sample cost is one
    /// activation+noise upload and one execute; nothing is re-staged.
    ///
    /// Note: the engine's own PJRT backend already amortises staging by
    /// construction (weights are staged once in `Engine::from_artifacts`
    /// and `run_vmm` only uploads activations), so it does not need this
    /// entry point; it exists for external batched drivers of the VMM
    /// artifact.
    pub fn run_pass_batch(
        &self,
        staged: &StagedPass,
        xs: &[Vec<f32>],
        noises: &[Vec<f32>],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(xs.len() == noises.len(), "batch shape");
        xs.iter()
            .zip(noises)
            .map(|(x, noise)| self.run_pass(staged, x, noise))
            .collect()
    }
}

/// `(act[128], wm_c[256,256], wm_1[256,256], wm_2[256,256], gain[2,256],
///  offset[2,256]) -> (scores[2],)` — the fused network; weights are
/// runtime parameters (HLO text elides large constants).
pub struct ModelExecutable {
    exe: xla::PjRtLoadedExecutable,
    staged: std::cell::RefCell<Option<([xla::PjRtBuffer; 5], Vec<xla::Literal>)>>,
}

impl ModelExecutable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable) -> ModelExecutable {
        ModelExecutable { exe, staged: std::cell::RefCell::new(None) }
    }

    /// Stage the model's weights/calibration once (device buffers).
    pub fn stage(&self, model: &crate::nn::weights::TrainedModel) -> anyhow::Result<()> {
        let client = self.exe.client();
        let dims2 = [c::K_LOGICAL as i64, c::N_COLS as i64];
        let cal_dims = [2i64, c::N_COLS as i64];
        let gain_flat: Vec<f32> = model.gain.concat();
        let offset_flat: Vec<f32> = model.offset.concat();
        let mk_lit = |data: &[f32], dims: &[i64]| -> anyhow::Result<xla::Literal> {
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape: {e}"))
        };
        let lits = vec![
            mk_lit(&model.pass_weights[0], &dims2)?,
            mk_lit(&model.pass_weights[1], &dims2)?,
            mk_lit(&model.pass_weights[2], &dims2)?,
            mk_lit(&gain_flat, &cal_dims)?,
            mk_lit(&offset_flat, &cal_dims)?,
        ];
        let mut bufs = Vec::with_capacity(5);
        for lit in &lits {
            bufs.push(
                client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow::anyhow!("stage: {e}"))?,
            );
        }
        let arr: [xla::PjRtBuffer; 5] =
            bufs.try_into().map_err(|_| anyhow::anyhow!("buffer count"))?;
        *self.staged.borrow_mut() = Some((arr, lits));
        Ok(())
    }

    pub fn run(&self, act: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(act.len() == c::MODEL_IN, "act length {}", act.len());
        let guard = self.staged.borrow();
        let (staged, _keep) = guard
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("call stage() before run()"))?;
        let client = self.exe.client();
        let act_lit = xla::Literal::vec1(act); // outlives the async copy
        let act_buf = client
            .buffer_from_host_literal(None, &act_lit)
            .map_err(|e| anyhow::anyhow!("stage act: {e}"))?;
        let args = [
            &act_buf, &staged[0], &staged[1], &staged[2], &staged[3], &staged[4],
        ];
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("model execute: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrong_buffer_count_is_typed_and_described() {
        let err: anyhow::Error = WrongBufferCount { expected: 4, got: 3 }.into();
        assert!(err.downcast_ref::<WrongBufferCount>().is_some());
        let msg = err.to_string();
        assert!(msg.contains("expected 4"), "{msg}");
        assert!(msg.contains("3 device buffers"), "{msg}");
    }
}
