//! API-compatible **stub** of the `xla` (PJRT) bindings.
//!
//! The real crate links the XLA extension library, which is not available
//! in offline build environments.  This stub mirrors exactly the API
//! surface `bss2::runtime::client` uses, and fails fast — with a clear
//! message — at the single entry point every PJRT code path goes through
//! (`PjRtClient::cpu`).  All downstream callers (engine construction,
//! selftest, benches, integration tests) already propagate the error or
//! skip toward the native backend, so the crate builds and tests fully
//! offline.  Swap the `xla` path dependency in `rust/Cargo.toml` for the
//! real bindings to re-enable the PJRT backend; no source changes needed.

use std::borrow::Borrow;
use std::path::Path;

/// Error type matching the real crate's `Display`-first usage.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT runtime unavailable (vendored xla stub); \
             use the native backend (--native) or link the real xla crate"
        ))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle.  `cpu()` is the sole constructor and always fails
/// in the stub, so no other method can ever be reached at runtime.
#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("buffer_from_host_literal"))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        PjRtClient(())
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute_b"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

/// Host literal.  Construction is infallible (mirrors the real crate);
/// anything that would touch the runtime errors out.
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("--native"), "{msg}");
    }

    #[test]
    fn literal_constructors_are_infallible() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(Literal::scalar(3.0).to_tuple1().is_err());
    }
}
