//! Continuous patient monitoring (the paper's motivating edge scenario):
//! classify a two-minute-interval stream of ECG windows, track detections
//! with a debouncing alarm, and report the battery-life projection of §V.
//!
//! ```bash
//! cargo run --release --example ecg_monitor -- [hours] [--native]
//! ```

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::metrics::Confusion;
use bss2::ecg::gen::generate_trace;
use bss2::power::energy::cr2032_years;
use bss2::runtime::ArtifactDir;
use bss2::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let hours: f64 = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(24.0);
    let cfg = EngineConfig {
        use_pjrt: !args.iter().any(|a| a == "--native"),
        ..Default::default()
    };
    let mut engine = Engine::from_artifacts(&ArtifactDir::default_location(), cfg)?;

    // Simulated patient: episodes of A-fib embedded in sinus rhythm
    // (paroxysmal pattern), one classification every 2 minutes (§V).
    let interval_s = 120.0;
    let checks = (hours * 3600.0 / interval_s) as usize;
    println!(
        "monitoring a simulated patient for {hours} h ({checks} checks at \
         2-minute intervals)\n"
    );

    let mut rng = SplitMix64::new(99);
    let mut in_episode = false;
    let mut confusion = Confusion::default();
    let mut energy_j = 0.0;
    let mut alarm_run = 0u32;
    let mut alarms = 0u32;

    for i in 0..checks {
        // Episode dynamics: enter an A-fib episode with p=2 %/check, leave
        // with p=15 %/check -> ~12 % duty cycle, multi-check episodes.
        if in_episode {
            if rng.unit() < 0.15 {
                in_episode = false;
            }
        } else if rng.unit() < 0.02 {
            in_episode = true;
        }
        let trace = generate_trace(500_000 + i as u64, in_episode, 1.0);
        let inf = engine.classify(&trace)?;
        confusion.add(inf.pred, in_episode as u8);
        energy_j += inf.energy.total_j();

        // Debounced alarm: 3 consecutive positive checks raise an alarm.
        alarm_run = if inf.pred == 1 { alarm_run + 1 } else { 0 };
        if alarm_run == 3 {
            alarms += 1;
            println!(
                "  t={:>6.1} h  ALARM: sustained atrial fibrillation \
                 (3 consecutive detections){}",
                i as f64 * interval_s / 3600.0,
                if in_episode { "" } else { "  [false alarm]" }
            );
        }
    }

    println!("\n--- monitoring summary -------------------------------------");
    println!("  checks:            {checks} ({:.1} h)", hours);
    println!(
        "  detection rate:    {:.1} %   (paper: 93.7 ± 0.7 %)",
        confusion.detection_rate() * 100.0
    );
    println!(
        "  false positives:   {:.1} %   (paper: 14.0 ± 1.0 %)",
        confusion.false_positive_rate() * 100.0
    );
    println!("  sustained alarms:  {alarms}");
    let per_check = energy_j / checks as f64;
    println!(
        "  energy:            {:.2} mJ/check -> CR2032 lifetime {:.1} years \
         (paper §V: ~5 years)",
        per_check * 1e3,
        cr2032_years(per_check, interval_s)
    );
    Ok(())
}
