//! Remote usage of the experiment execution service (paper §II-D: hosts
//! exchange serialized experiment data with the mobile system over the
//! USB-Ethernet link).  Spawns the service in-process — backed by a fleet
//! of `--chips N` engine replicas — connects as several concurrent
//! clients, streams classification requests, and prints the per-chip work
//! spread plus the fleet stats.
//!
//! ```bash
//! cargo run --release --example remote_client -- [n_requests] [--native] [--chips 4]
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{Client, Service};
use bss2::ecg::gen::TraceStream;
use bss2::fleet::FleetConfig;
use bss2::runtime::ArtifactDir;
use bss2::util::cli::Args;
use bss2::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n: usize = args
        .positional
        .first()
        .and_then(|a| a.parse().ok())
        .unwrap_or(20);
    let use_pjrt = !args.flag("native");
    let chips = args.usize_or("chips", 2)?;

    let dir = ArtifactDir::default_location();
    let svc = Service::start_fleet(
        "127.0.0.1:0",
        FleetConfig { chips, ..Default::default() },
        move |chip| {
            Engine::from_artifacts(
                &dir,
                EngineConfig { use_pjrt, ..Default::default() }.for_chip(chip),
            )
        },
    )?;
    println!("service listening on {} ({chips} chips)", svc.addr);

    let mut client = Client::connect(&svc.addr)?;
    let pong = client.call("{\"cmd\":\"ping\"}")?;
    println!("ping -> {pong}");

    // Concurrent clients: 2 per chip keeps every replica busy.
    let n_clients = (2 * chips).max(2);
    let per_client = n.div_ceil(n_clients);
    let correct = Arc::new(AtomicUsize::new(0));
    let addr = svc.addr;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for cl_id in 0..n_clients {
        let correct = correct.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<usize>> {
            let mut cl = Client::connect(&addr)?;
            let mut chips_hit = Vec::new();
            let stream = TraceStream::new(7 + cl_id as u64, 1.0);
            for (i, trace) in stream.take(per_client).enumerate() {
                let reply = cl.classify(&trace)?;
                let ok = reply.get("ok") == Some(&Json::Bool(true));
                let shed = reply.get("shed") == Some(&Json::Bool(true));
                if shed {
                    // Backpressure: honour the hint, then move on.
                    let us = reply
                        .get("retry_after_us")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(300.0);
                    std::thread::sleep(std::time::Duration::from_micros(us as u64));
                    continue;
                }
                anyhow::ensure!(ok, "client {cl_id} req {i} failed: {reply}");
                if let Some(chip) = reply.get("chip").and_then(|v| v.as_usize()) {
                    chips_hit.push(chip);
                }
                let pred =
                    reply.get("pred").and_then(|p| p.as_f64()).unwrap_or(-1.0);
                if pred as u8 == trace.label {
                    correct.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(chips_hit)
        }));
    }
    let mut per_chip = vec![0usize; chips];
    let mut total = 0usize;
    for h in handles {
        for chip in h.join().expect("client thread panicked")? {
            per_chip[chip] += 1;
            total += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    // wall/total is aggregate throughput across n_clients concurrent
    // clients, not a per-request round trip.
    println!(
        "\nserved {total} requests in {:.2} s ({:.0} req/s aggregate over \
         {n_clients} clients), {}/{total} labels matched",
        wall,
        total as f64 / wall.max(1e-9),
        correct.load(Ordering::Relaxed)
    );
    println!("work spread: {per_chip:?} requests per chip");

    // Batched path: one classify_batch request runs as a single program
    // on one chip, amortising per-layer weight reconfiguration (the
    // reply reports partial acceptance under load).
    let batch: Vec<_> = TraceStream::new(99, 1.0).take(8).collect();
    let reply = client.classify_batch(&batch)?;
    anyhow::ensure!(
        reply.get("ok") == Some(&Json::Bool(true)),
        "classify_batch failed: {reply}"
    );
    println!(
        "classify_batch: {}/{} accepted on chip {}, {:.0} µs/sample \
         (single-trace path: ~276 µs)",
        reply.get("accepted").and_then(|v| v.as_usize()).unwrap_or(0),
        batch.len(),
        reply.get("chip").and_then(|v| v.as_usize()).unwrap_or(0),
        reply
            .get("time_us_per_sample")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0),
    );

    let stats = client.call("{\"cmd\":\"stats\"}")?;
    println!("service stats: {stats}");
    let fleet = client.call("{\"cmd\":\"fleet_stats\"}")?;
    println!("fleet stats:   {fleet}");
    svc.stop();
    Ok(())
}
