//! Remote usage of the experiment execution service (paper §II-D: hosts
//! exchange serialized experiment data with the mobile system over the
//! USB-Ethernet link).  Spawns the service in-process, connects as a
//! client, streams classification requests, and prints the service stats.
//!
//! ```bash
//! cargo run --release --example remote_client -- [n_requests] [--native]
//! ```

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::coordinator::service::{Client, Service};
use bss2::ecg::gen::TraceStream;
use bss2::runtime::ArtifactDir;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(20);
    let use_pjrt = !args.iter().any(|a| a == "--native");

    let dir = ArtifactDir::default_location();
    let svc = Service::start("127.0.0.1:0", move || {
        Engine::from_artifacts(
            &dir,
            EngineConfig { use_pjrt, ..Default::default() },
        )
    })?;
    println!("service listening on {}", svc.addr);

    let mut client = Client::connect(&svc.addr)?;
    let pong = client.call("{\"cmd\":\"ping\"}")?;
    println!("ping -> {pong}");

    let t0 = std::time::Instant::now();
    let mut correct = 0;
    for (i, trace) in TraceStream::new(7, 1.0).take(n).enumerate() {
        let reply = client.classify(&trace)?;
        let ok = reply
            .get("ok")
            .and_then(|v| match v {
                bss2::util::json::Json::Bool(b) => Some(*b),
                _ => None,
            })
            .unwrap_or(false);
        anyhow::ensure!(ok, "request {i} failed: {reply}");
        let pred = reply.get("pred").and_then(|p| p.as_f64()).unwrap_or(-1.0);
        if pred as u8 == trace.label {
            correct += 1;
        }
        if i < 5 {
            println!("  req {i}: {reply}");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {n} requests in {:.2} s ({:.2} ms round trip each), \
         {correct}/{n} labels matched",
        wall,
        wall * 1e3 / n as f64
    );
    let stats = client.call("{\"cmd\":\"stats\"}")?;
    println!("service stats: {stats}");
    svc.stop();
    Ok(())
}
