//! Quickstart: load the trained artifacts and classify a handful of
//! synthetic ECG traces through the full mobile-system dataflow.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::gen::TraceStream;
use bss2::runtime::ArtifactDir;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactDir::default_location();
    println!("loading artifacts from {} ...", dir.root.display());
    let mut engine = Engine::from_artifacts(&dir, EngineConfig::default())?;

    println!("classifying 10 synthetic patient windows (batch size 1):\n");
    let mut correct = 0;
    for (i, trace) in TraceStream::new(2024, 1.0).take(10).enumerate() {
        let inf = engine.classify(&trace)?;
        let verdict = match inf.pred {
            1 => "ATRIAL FIBRILLATION",
            _ => "sinus rhythm",
        };
        let ok = inf.pred == trace.label;
        correct += ok as usize;
        println!(
            "  window {i}: {verdict:<20} scores=[{:+6.1} {:+6.1}]  \
             {:>4.0} µs  {:.2} mJ  {}",
            inf.scores[0],
            inf.scores[1],
            inf.sim_time_s * 1e6,
            inf.energy.total_j() * 1e3,
            if ok { "ok" } else { "label differs" }
        );
    }
    println!("\n{correct}/10 match the generator label");
    println!("paper reference: 276 µs and 1.56 mJ per classification (Table 1)");
    Ok(())
}
