//! Power report: run the paper's §IV measurement procedure (500-trace
//! block, batch size 1) on the held-out artifact test set and print the
//! full Table 1, plus the §V platform comparison.
//!
//! ```bash
//! cargo run --release --example power_report -- [n_traces] [--native]
//! ```

use bss2::coordinator::batch::run_block;
use bss2::coordinator::engine::{Engine, EngineConfig};
use bss2::ecg::dataset::Dataset;
use bss2::power::energy::cr2032_years;
use bss2::runtime::ArtifactDir;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|a| a.parse().ok())
        .unwrap_or(500);
    let dir = ArtifactDir::default_location();
    let cfg = EngineConfig {
        use_pjrt: !args.iter().any(|a| a == "--native"),
        ..Default::default()
    };

    let ds = Dataset::load(&dir.ecg_test())?;
    let traces: Vec<_> = ds
        .traces
        .iter()
        .take(n)
        .map(|t| (t.clone(), t.label))
        .collect();
    println!(
        "measuring a block of {} held-out traces (afib fraction {:.2}) ...\n",
        traces.len(),
        ds.afib_fraction()
    );

    let mut engine = Engine::from_artifacts(&dir, cfg)?;
    let rep = run_block(&mut engine, &traces)?;
    println!("{}", rep.table1());

    println!("\n§V platform comparison (energy per classification):");
    for (name, j, ratio) in bss2::baselines::comparison_table(rep.energy_total_j)
    {
        println!("  {:<38} {:>12.4} mJ  {:>8.1}x", name, j * 1e3, ratio);
    }
    println!(
        "\nCR2032 at 2-minute monitoring intervals: {:.1} years (paper: ~5)",
        cr2032_years(rep.energy_total_j, 120.0)
    );
    Ok(())
}
