"""L2 model tests: weight packing, forward flavours, fused == 3-pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.hwmodel as hw
from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(3))


@pytest.fixture(scope="module")
def params_q(params):
    return {k: jnp.round(jnp.clip(v, -1, 1) * hw.W_MAX)
            for k, v in params.items()}


@pytest.fixture(scope="module")
def calib():
    return model.default_calib(jax.random.PRNGKey(4))


def _rand_act(seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 32, hw.MODEL_IN).astype(np.float32))


# --- packing ---------------------------------------------------------------

def test_pack_conv_geometry(params_q):
    m = np.asarray(model.pack_conv(params_q["wc"]))
    assert m.shape == (hw.K_LOGICAL, hw.N_COLS)
    # Only the first MODEL_IN rows may carry conv weights.
    assert np.all(m[hw.MODEL_IN:] == 0)
    # Column p*C+o gets kernel taps of channel o at position p.
    wc = np.asarray(params_q["wc"])
    p, o = 5, 3
    col = m[:, p * hw.CONV_CHANNELS + o]
    start = p * hw.CONV_STRIDE - hw.CONV_PAD
    for c in range(hw.ECG_CHANNELS):
        for t in range(hw.CONV_KERNEL):
            ti = start + t
            if 0 <= ti < hw.POOLED_LEN:
                assert col[c * hw.POOLED_LEN + ti] == wc[o, c, t]


def test_pack_conv_np_matches_jax(params_q):
    a = np.asarray(model.pack_conv(params_q["wc"]))
    b = model.pack_conv_np(np.asarray(params_q["wc"]))
    np.testing.assert_array_equal(a, b)


def test_pack_conv_replication(params_q):
    """The same kernel is arranged 32x on the substrate (paper Fig 6)."""
    m = np.asarray(model.pack_conv(params_q["wc"]))
    # Interior positions (no padding truncation) are shifted copies.
    p0, p1 = 4, 10
    col0 = m[:, p0 * hw.CONV_CHANNELS]
    col1 = m[:, p1 * hw.CONV_CHANNELS]
    shift = (p1 - p0) * hw.CONV_STRIDE
    np.testing.assert_array_equal(
        col0[0:hw.POOLED_LEN - shift], col1[shift:hw.POOLED_LEN])


def test_pack_fc1_blocks(params_q):
    m = np.asarray(model.pack_fc1(params_q["w1"]))
    w1 = np.asarray(params_q["w1"])
    np.testing.assert_array_equal(m[0:128, 0:123], w1[0:128])
    np.testing.assert_array_equal(m[128:256, 123:246], w1[128:256])
    assert np.all(m[0:128, 123:246] == 0)
    assert np.all(m[128:256, 0:123] == 0)
    assert np.all(m[:, 246:] == 0)


def test_pack_fc2_block(params_q):
    m = np.asarray(model.pack_fc2(params_q["w2"]))
    np.testing.assert_array_equal(m[0:123, 246:256], np.asarray(params_q["w2"]))
    assert np.all(m[123:, :] == 0)
    assert np.all(m[:, :246] == 0)


# --- forward flavours ------------------------------------------------------

def test_forward_hw_pallas_equals_ref(params_q, calib):
    act = _rand_act(0)
    noise = jnp.zeros((3, hw.N_COLS))
    a = model.forward_hw(params_q, act, calib, noise)
    b = model.forward_hw(params_q, act, calib, noise,
                         vmm=ref.analog_vmm_ref)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forward_trainable_matches_hw_when_quantised(params, params_q, calib):
    """Same maths: trainable fwd with max-pool vs hw fwd with avg-pool must
    agree on the *pre-pool* path; compare via a distribution check on many
    inputs (scores correlated, same scale)."""
    noise = jnp.zeros((3, hw.N_COLS))
    for seed in range(4):
        act = _rand_act(seed)
        hw_scores = np.asarray(model.forward_hw(params_q, act, calib, noise))
        tr_scores = np.asarray(model.forward_trainable(params, act, calib,
                                                       noise))
        # max >= mean over each pool group, both within ADC range
        assert np.all(tr_scores >= hw_scores - 1e-5)
        assert np.all(np.abs(hw_scores) <= 127.0)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_forward_hw_deterministic_and_bounded(seed, params_q, calib):
    act = _rand_act(seed)
    noise = jnp.zeros((3, hw.N_COLS))
    s1 = np.asarray(model.forward_hw(params_q, act, calib, noise,
                                     vmm=ref.analog_vmm_ref))
    s2 = np.asarray(model.forward_hw(params_q, act, calib, noise,
                                     vmm=ref.analog_vmm_ref))
    np.testing.assert_array_equal(s1, s2)
    assert s1.shape == (hw.N_CLASSES,)
    assert np.all(np.abs(s1) <= 127.0)


def test_noise_changes_scores(params_q, calib):
    act = _rand_act(1)
    k = jax.random.PRNGKey(0)
    n1 = hw.NOISE_SIGMA * jax.random.normal(k, (3, hw.N_COLS))
    s0 = np.asarray(model.forward_hw(params_q, act, calib,
                                     jnp.zeros((3, hw.N_COLS)),
                                     vmm=ref.analog_vmm_ref))
    s1 = np.asarray(model.forward_hw(params_q, act, calib, n1,
                                     vmm=ref.analog_vmm_ref))
    assert not np.array_equal(s0, s1)
    # ... but only by a few LSB thanks to the output average-pooling.
    assert np.all(np.abs(s0 - s1) < 10 * hw.NOISE_SIGMA)


def test_grad_flow_all_params(params, calib):
    act = _rand_act(2)
    noise = jnp.zeros((3, hw.N_COLS))

    def loss(p):
        return model.forward_trainable(p, act, calib, noise).sum()

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert float(jnp.abs(v).sum()) > 0.0, f"dead gradient for {k}"


def test_mock_mode_runs(params):
    s = np.asarray(model.forward_mock(params, _rand_act(5)))
    assert s.shape == (hw.N_CLASSES,)


def test_fused_fn_equals_composition(params_q, calib):
    pq_np = {k: np.asarray(v) for k, v in params_q.items()}
    calib_np = {k: np.asarray(v) for k, v in calib.items()}
    fn = model.fused_inference_fn(pq_np, calib_np)
    zero = jnp.zeros((3, hw.N_COLS))
    for seed in range(3):
        act = _rand_act(seed + 10)
        fused = np.asarray(fn(act)[0])
        composed = np.asarray(model.forward_hw(params_q, act, calib, zero))
        np.testing.assert_array_equal(fused, composed)


def test_fused_param_fn_equals_baked(params_q, calib):
    """The exportable parameterised fused fn (weights as arguments — HLO
    text cannot carry large constants) must equal the baked closure."""
    pq_np = {k: np.asarray(v) for k, v in params_q.items()}
    calib_np = {k: np.asarray(v) for k, v in calib.items()}
    baked = model.fused_inference_fn(pq_np, calib_np)
    param = model.fused_inference_param_fn()
    wm_c = model.pack_conv(params_q["wc"])
    wm_1 = model.pack_fc1(params_q["w1"])
    wm_2 = model.pack_fc2(params_q["w2"])
    for seed in range(3):
        act = _rand_act(seed + 20)
        a = np.asarray(baked(act)[0])
        b = np.asarray(param(act, wm_c, wm_1, wm_2, calib["gain"],
                             calib["offset"])[0])
        np.testing.assert_array_equal(a, b)
