"""Synthetic ECG generator + FPGA preprocessing mirror tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.hwmodel as hw
from compile import data


def test_prng_splitmix64_reference():
    """Golden values — the rust SplitMix64 must produce the same stream."""
    rng = data.SplitMix64(0)
    vals = [rng.next_u64() for _ in range(3)]
    assert vals == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]
    rng = data.SplitMix64(42)
    assert rng.next_u64() == 0xBDD732262FEB6E95


def test_prng_uniform_range():
    rng = data.SplitMix64(7)
    us = [rng.uniform() for _ in range(1000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert 0.4 < float(np.mean(us)) < 0.6


def test_prng_gauss_moments():
    rng = data.SplitMix64(8)
    gs = np.array([rng.gauss() for _ in range(4000)])
    assert abs(gs.mean()) < 0.1
    assert 0.9 < gs.std() < 1.1


def test_trace_determinism():
    a, la = data.generate_trace(123, True)
    b, lb = data.generate_trace(123, True)
    np.testing.assert_array_equal(a, b)
    assert la == lb == 1


def test_trace_shape_and_range():
    t, label = data.generate_trace(5, False)
    assert t.shape == (hw.ECG_CHANNELS, hw.ECG_WINDOW)
    assert t.dtype == np.uint16
    assert t.min() >= 0 and t.max() <= 4095
    assert label == 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), afib=st.booleans())
def test_trace_is_12bit_and_active(seed, afib):
    t, _ = data.generate_trace(seed, afib)
    assert t.max() <= 4095
    # Signal must actually contain beats (QRS deflections).
    assert int(t[0].max()) - int(t[0].min()) > 200


def test_dataset_balance_and_labels():
    xs, ys = data.generate_dataset(20, seed=1)
    assert xs.shape == (20, hw.ECG_CHANNELS, hw.ECG_WINDOW)
    assert ys.sum() == 10  # alternating labels at afib_fraction=0.5


def test_class_statistics_differ():
    """A-fib traces must differ in the feature statistics the classifier
    exploits: higher mean activation (rapid ventricular response + f-waves)
    and more active bins."""
    n = 60
    xs, ys = data.generate_dataset(n, seed=77)
    acts = data.preprocess_batch(xs)
    mean_act = acts.mean(axis=1)
    assert mean_act[ys == 1].mean() > mean_act[ys == 0].mean() + 0.5
    hi = (acts >= 10).mean(axis=1)
    assert hi[ys == 1].mean() > hi[ys == 0].mean()


# --- preprocessing (Fig 7 mirror) -------------------------------------------

def test_preprocess_shape_range():
    t, _ = data.generate_trace(9, True)
    act = data.preprocess(t)
    assert act.shape == (hw.MODEL_IN,)
    assert act.min() >= 0 and act.max() <= hw.X_MAX


def test_preprocess_constant_trace_is_zero():
    """Constant input -> zero derivative -> zero activations."""
    t = np.full((hw.ECG_CHANNELS, hw.ECG_WINDOW), 2048, np.uint16)
    np.testing.assert_array_equal(data.preprocess(t), 0)


def test_preprocess_baseline_suppression():
    """Slow baseline wander must be (mostly) removed by the derivative."""
    tgrid = np.arange(hw.ECG_WINDOW) / hw.ECG_FS_HZ
    wander = (300 * np.sin(2 * np.pi * 0.3 * tgrid)).astype(np.int32)
    t = np.clip(2048 + wander, 0, 4095).astype(np.uint16)
    tt = np.stack([t, t])
    act = data.preprocess(tt)
    assert act.max() <= 2, "baseline wander must not excite the features"


def test_preprocess_spike_detected():
    """A QRS-like spike must saturate its pooled bin."""
    t = np.full((hw.ECG_CHANNELS, hw.ECG_WINDOW), 2048, np.uint16)
    t[0, 640:643] = 3500   # sharp deflection inside pooled bin 20
    act = data.preprocess(t).reshape(2, hw.POOLED_LEN)
    assert act[0, 20] == hw.X_MAX
    assert act[0, 25] == 0


def test_preprocess_is_shift_quantised():
    """Quantisation is a plain right-shift (FPGA barrel shifter)."""
    t, _ = data.generate_trace(33, False)
    x = t.astype(np.int32)
    d = np.diff(x, axis=1, prepend=x[:, :1])
    d = d.reshape(2, hw.POOLED_LEN, hw.POOL_WINDOW)
    pooled = d.max(axis=2) - d.min(axis=2)
    expect = np.clip(pooled >> hw.PREPROC_SHIFT, 0, hw.X_MAX).reshape(-1)
    np.testing.assert_array_equal(data.preprocess(t), expect)
