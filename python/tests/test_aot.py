"""AOT exporter tests: HLO text round-trips through the XLA text parser and
reproduces the kernel numerics (the same path the rust runtime uses)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

import compile.hwmodel as hw
from compile import aot, model
from compile.kernels.analog_vmm import analog_vmm


@pytest.fixture(scope="module")
def vmm_hlo(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("aot"))
    path = aot.export_vmm(out)
    return open(path).read()


def test_vmm_hlo_entry_signature(vmm_hlo):
    assert "HloModule" in vmm_hlo
    assert "f32[256,256]" in vmm_hlo        # weight operand
    assert "->(f32[256]{0})" in vmm_hlo.replace(" ", "")


def test_vmm_hlo_has_no_custom_calls(vmm_hlo):
    """interpret=True must lower to plain HLO the CPU client can run."""
    assert "custom-call" not in vmm_hlo or "Sharding" in vmm_hlo


def test_vmm_kernel_matches_closed_form():
    """The kernel the HLO was lowered from matches the closed-form maths
    (the rust integration tests replay the exported test vectors through the
    compiled artifact itself — this anchors the python side of that chain)."""
    rng = np.random.default_rng(3)
    x = rng.integers(0, 32, hw.K_LOGICAL).astype(np.float32)
    w = rng.integers(-63, 64, (hw.K_LOGICAL, hw.N_COLS)).astype(np.float32)
    gain = np.ones(hw.N_COLS, np.float32)
    offset = np.zeros(hw.N_COLS, np.float32)
    noise = np.zeros(hw.N_COLS, np.float32)
    scale = np.float32(0.01)
    got = np.asarray(analog_vmm(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(gain), jnp.asarray(offset),
                                jnp.asarray(noise), jnp.asarray(scale)))
    acc = x @ w
    v = np.clip(scale * acc, -hw.MEMBRANE_CLIP, hw.MEMBRANE_CLIP)
    want = np.clip(np.round(v), hw.ADC_MIN, hw.ADC_MAX)
    np.testing.assert_array_equal(got, want)


def test_export_testvectors(tmp_path):
    out = str(tmp_path)
    path = aot.export_vmm_testvec(out, n_cases=2, seed=1)
    blob = json.load(open(path))
    assert blob["k"] == hw.K_LOGICAL and blob["n"] == hw.N_COLS
    for case in blob["cases"]:
        assert len(case["x"]) == hw.K_LOGICAL
        assert len(case["w"]) == hw.K_LOGICAL * hw.N_COLS
        assert len(case["expected"]) == hw.N_COLS
        # Expected values are valid ADC counts.
        e = np.asarray(case["expected"])
        assert e.min() >= hw.ADC_MIN and e.max() <= hw.ADC_MAX


def test_full_export_against_trained_weights(tmp_path):
    """If real artifacts exist, verify manifest hashes and model testvec."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.exists(os.path.join(art, "manifest.json")):
        pytest.skip("artifacts not built")
    manifest = json.load(open(os.path.join(art, "manifest.json")))
    assert manifest["hw"]["k_logical"] == hw.K_LOGICAL
    assert manifest["hw"]["n_cols"] == hw.N_COLS
    assert manifest["hw"]["macs"]["total"] == hw.MACS_TOTAL
    for fname, sha in manifest["files"].items():
        fpath = os.path.join(art, fname)
        assert os.path.exists(fpath), f"missing artifact {fname}"
        assert aot._sha256(fpath) == sha, f"hash mismatch for {fname}"

    # Replay the exported model test vectors through forward_hw.
    weights_meta, pq, calib = aot.load_weights(art)
    pq_j = {k: jnp.asarray(v) for k, v in pq.items()}
    calib_j = {k: jnp.asarray(v) for k, v in calib.items()}
    zero = jnp.zeros((3, hw.N_COLS))
    cases = json.load(open(os.path.join(art, "model_testvec.json")))["cases"]
    from compile.kernels import ref
    for case in cases:
        scores = np.asarray(model.forward_hw(
            pq_j, jnp.asarray(np.asarray(case["act"], np.float32)),
            calib_j, zero, tuple(weights_meta["scales"]),
            vmm=ref.analog_vmm_ref))
        np.testing.assert_array_equal(scores, np.asarray(case["scores"]))


def test_weights_are_on_hardware_grid(tmp_path):
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.exists(os.path.join(art, "weights.json")):
        pytest.skip("artifacts not built")
    _, pq, calib = aot.load_weights(art)
    for k, v in pq.items():
        assert np.all(v == np.round(v)), f"{k} not integer"
        assert np.abs(v).max() <= hw.W_MAX, f"{k} exceeds 6-bit range"
    assert calib["gain"].shape == (2, hw.N_COLS)
    assert calib["offset"].shape == (2, hw.N_COLS)
