"""Training-loop tests: optimizer, scale calibration, metrics, smoke-train."""

import jax
import jax.numpy as jnp
import numpy as np

import compile.hwmodel as hw
from compile import data, model, train
from compile.kernels import ref


def test_adam_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(params)
    for _ in range(400):
        g = {"w": 2 * params["w"]}
        params, opt = train.adam_update(params, g, opt, lr=5e-2)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adam_bias_correction_first_step():
    """First Adam step must be ~lr * sign(grad) (bias-corrected)."""
    params = {"w": jnp.asarray([0.0])}
    opt = train.adam_init(params)
    new, _ = train.adam_update(params, {"w": jnp.asarray([10.0])}, opt, lr=1e-2)
    np.testing.assert_allclose(float(new["w"][0]), -1e-2, rtol=1e-3)


def test_calibrate_scales_targets_range():
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    calib = model.default_calib(jax.random.PRNGKey(1))
    xs, _ = data.generate_dataset(64, seed=2)
    acts = data.preprocess_batch(xs)
    scales = train.calibrate_scales(params, acts, calib)
    assert len(scales) == 3 and all(s > 0 for s in scales)
    # Verify the conv layer's 99th-percentile |v| is near the target.
    q = {k: np.asarray(ref.quantize_weights(v)) for k, v in params.items()}
    wm_c = model.pack_conv_np(q["wc"])
    x0 = np.zeros((len(acts), hw.K_LOGICAL), np.float32)
    x0[:, 0:hw.MODEL_IN] = acts
    v = scales[0] * (x0 @ wm_c) * np.asarray(calib["gain"])[0]
    assert 80.0 < np.percentile(np.abs(v), 99) < 125.0


def test_metrics_from_scores():
    scores = np.array([[1, 0], [0, 1], [1, 0], [0, 1]])
    labels = np.array([0, 0, 1, 1])
    det, fp, acc = train.metrics_from_scores(scores, labels)
    assert det == 0.5 and fp == 0.5 and acc == 0.5


def test_metrics_perfect():
    scores = np.array([[9, 0], [0, 9]])
    labels = np.array([0, 1])
    det, fp, acc = train.metrics_from_scores(scores, labels)
    assert (det, fp, acc) == (1.0, 0.0, 1.0)


def test_single_training_step_reduces_loss():
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    calib = model.default_calib(jax.random.PRNGKey(1))
    xs, ys = data.generate_dataset(64, seed=5)
    acts = jnp.asarray(data.preprocess_batch(xs))
    scales = train.calibrate_scales(params, np.asarray(acts), calib)
    step, batch_loss = train.make_step(calib, scales)
    opt = train.adam_init(params)
    noise = jnp.zeros((64, 3, hw.N_COLS))
    labels = jnp.asarray(ys)
    l0 = float(batch_loss(params, acts, noise, labels))
    p, o = params, opt
    for _ in range(15):
        p, o, loss = step(p, o, acts, noise, labels)
    l1 = float(batch_loss(p, acts, noise, labels))
    assert l1 < l0, f"loss did not decrease: {l0} -> {l1}"


def test_pos_weight_shifts_operating_point():
    """Higher pos_weight must penalise missed A-fib more."""
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    calib = model.default_calib(jax.random.PRNGKey(1))
    scales = (0.05, 0.1, 0.1)
    _, loss_plain = train.make_step(calib, scales, pos_weight=1.0)
    _, loss_weighted = train.make_step(calib, scales, pos_weight=3.0)
    act = jnp.asarray(np.random.default_rng(0).integers(
        0, 32, (4, hw.MODEL_IN)).astype(np.float32))
    noise = jnp.zeros((4, 3, hw.N_COLS))
    pos_labels = jnp.asarray([1, 1, 1, 1])
    lp = float(loss_plain(params, act, noise, pos_labels))
    lw = float(loss_weighted(params, act, noise, pos_labels))
    np.testing.assert_allclose(lw, 3.0 * lp, rtol=1e-5)


def test_ecg_bin_roundtrip(tmp_path):
    xs, ys = data.generate_dataset(4, seed=6)
    path = tmp_path / "t.bin"
    train.write_ecg_bin(str(path), xs, ys)
    raw = path.read_bytes()
    import struct
    magic, n, ch, w = struct.unpack_from("<IIII", raw, 0)
    assert magic == train.MAGIC and n == 4
    assert ch == hw.ECG_CHANNELS and w == hw.ECG_WINDOW
    off = 16
    for i in range(n):
        label = raw[off]; off += 1
        assert label == ys[i]
        t = np.frombuffer(raw, "<u2", ch * w, off).reshape(ch, w)
        np.testing.assert_array_equal(t, xs[i])
        off += ch * w * 2
