"""Kernel vs reference — the CORE correctness signal (L1).

Hypothesis sweeps the analog-VMM pallas kernel against the pure-jnp oracle
over shapes, value ranges and configuration flags; plus directed tests of
every analog effect (saturation, ADC clipping, ReLU-in-ADC, gain/offset/noise
application order).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import compile.hwmodel as hw
from compile.kernels.analog_vmm import analog_vmm, vmem_report, TILE_N
from compile.kernels.ref import analog_vmm_ref, quantize_weights, requantize


def _rand_case(rng, k, n, x_hi=hw.X_MAX, w_hi=hw.W_MAX):
    x = rng.integers(0, x_hi + 1, k).astype(np.float32)
    w = rng.integers(-w_hi, w_hi + 1, (k, n)).astype(np.float32)
    gain = (1 + 0.06 * rng.standard_normal(n)).astype(np.float32)
    offset = (2.0 * rng.standard_normal(n)).astype(np.float32)
    noise = (2.0 * rng.standard_normal(n)).astype(np.float32)
    scale = np.float32(0.001 + 0.05 * rng.random())
    return x, w, gain, offset, noise, scale


@settings(max_examples=12, deadline=None)
@given(
    k=st.sampled_from([1, 8, 64, 123, 128, 200, 256]),
    n=st.sampled_from([1, 16, 128, 130, 256]),
    seed=st.integers(0, 2**31 - 1),
    relu=st.booleans(),
)
def test_kernel_matches_ref_shapes(k, n, seed, relu):
    """Pallas kernel == oracle over ragged/odd shapes and both ADC modes."""
    rng = np.random.default_rng(seed)
    x, w, gain, offset, noise, scale = _rand_case(rng, k, n)
    got = analog_vmm(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gain),
                     jnp.asarray(offset), jnp.asarray(noise),
                     jnp.asarray(scale), relu_in_adc=relu)
    want = analog_vmm_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gain),
                          jnp.asarray(offset), jnp.asarray(noise),
                          jnp.asarray(scale), relu_in_adc=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-4, 1.0))
def test_kernel_scale_sweep(seed, scale):
    """Scales from deep-linear to fully-saturating regimes."""
    rng = np.random.default_rng(seed)
    x, w, gain, offset, noise, _ = _rand_case(rng, hw.K_LOGICAL, hw.N_COLS)
    s = np.float32(scale)
    got = analog_vmm(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gain),
                     jnp.asarray(offset), jnp.asarray(noise), jnp.asarray(s))
    want = analog_vmm_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gain),
                          jnp.asarray(offset), jnp.asarray(noise),
                          jnp.asarray(s))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_output_range_signed():
    rng = np.random.default_rng(0)
    x, w, gain, offset, noise, _ = _rand_case(rng, 256, 256)
    out = np.asarray(analog_vmm(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(gain), jnp.asarray(offset),
                                jnp.asarray(noise), jnp.asarray(np.float32(1.0))))
    assert out.min() >= hw.ADC_MIN and out.max() <= hw.ADC_MAX
    assert np.all(out == np.round(out)), "ADC counts must be integers"


def test_output_range_relu():
    rng = np.random.default_rng(1)
    x, w, gain, offset, noise, _ = _rand_case(rng, 256, 256)
    out = np.asarray(analog_vmm(jnp.asarray(x), jnp.asarray(w),
                                jnp.asarray(gain), jnp.asarray(offset),
                                jnp.asarray(noise), jnp.asarray(np.float32(1.0)),
                                relu_in_adc=True))
    assert out.min() >= 0.0, "ReLU-in-ADC clamps negative deflections"


def test_zero_input_gives_offset_noise_only():
    """No events -> membranes stay at V_reset + offset + noise."""
    n = 64
    x = jnp.zeros(128)
    w = jnp.ones((128, n)) * 63.0
    gain = jnp.ones(n)
    offset = jnp.full(n, 3.0)
    noise = jnp.full(n, -1.0)
    out = np.asarray(analog_vmm(x, w, gain, offset, noise,
                                jnp.asarray(np.float32(0.01))))
    np.testing.assert_array_equal(out, np.full(n, 2.0))


def test_linearity_before_saturation():
    """In the linear regime the ADC output is proportional to the input."""
    k, n = 128, 32
    w = jnp.ones((k, n)) * 10.0
    gain = jnp.ones(n)
    zero = jnp.zeros(n)
    s = jnp.asarray(np.float32(0.01))
    x1 = jnp.full(k, 4.0)
    x2 = jnp.full(k, 8.0)
    o1 = np.asarray(analog_vmm(x1, w, gain, zero, zero, s))
    o2 = np.asarray(analog_vmm(x2, w, gain, zero, zero, s))
    np.testing.assert_allclose(o2, 2 * o1, atol=1.0)


def test_membrane_saturation_dominates_adc():
    """Huge accumulation saturates at the membrane clip, then the ADC clamps."""
    k, n = 256, 8
    x = jnp.full(k, float(hw.X_MAX))
    w = jnp.full((k, n), float(hw.W_MAX))
    out = np.asarray(analog_vmm(x, w, jnp.ones(n), jnp.zeros(n), jnp.zeros(n),
                                jnp.asarray(np.float32(1.0))))
    np.testing.assert_array_equal(out, np.full(n, float(hw.ADC_MAX)))
    out_neg = np.asarray(analog_vmm(x, -w, jnp.ones(n), jnp.zeros(n),
                                    jnp.zeros(n), jnp.asarray(np.float32(1.0))))
    np.testing.assert_array_equal(out_neg, np.full(n, float(hw.ADC_MIN)))


def test_gain_is_per_column():
    k, n = 64, 4
    x = jnp.full(k, 10.0)
    w = jnp.ones((k, n))
    gain = jnp.asarray([0.5, 1.0, 2.0, 4.0], jnp.float32)
    out = np.asarray(analog_vmm(x, w, gain, jnp.zeros(n), jnp.zeros(n),
                                jnp.asarray(np.float32(0.1))))
    np.testing.assert_allclose(out, [32.0, 64.0, 127.0, 127.0])


def test_quantize_weights_grid():
    w = jnp.asarray([-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0])
    q = np.asarray(quantize_weights(w))
    np.testing.assert_array_equal(q, [-63, -63, -32, 0, 32, 63, 63])


def test_requantize_shift():
    adc = jnp.asarray([-50.0, -1.0, 0.0, 3.0, 4.0, 127.0, 124.0])
    out = np.asarray(requantize(adc))
    np.testing.assert_array_equal(out, [0, 0, 0, 0, 1, 31, 31])


def test_vmem_report_static():
    r = vmem_report()
    assert r["vmem_bytes_per_program"] < 16 * 2**20, "tile must fit VMEM"
    assert r["grid_programs"] == hw.N_COLS // TILE_N
    assert r["flops_per_program"] == 2 * hw.K_LOGICAL * TILE_N


@pytest.mark.parametrize("k,n", [(256, 256), (128, 384), (256, 512)])
def test_chip_sized_shapes(k, n):
    """Shapes the partitioner actually emits (half-array and multi-half)."""
    rng = np.random.default_rng(k * 1000 + n)
    x, w, gain, offset, noise, scale = _rand_case(rng, k, n)
    got = analog_vmm(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gain),
                     jnp.asarray(offset), jnp.asarray(noise),
                     jnp.asarray(scale))
    want = analog_vmm_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gain),
                          jnp.asarray(offset), jnp.asarray(noise),
                          jnp.asarray(scale))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
