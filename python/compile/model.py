"""L2: the ECG CDNN of paper Fig 6, expressed over the analog-array VMM.

Network (DESIGN.md §3):

    input  u5[128]            (2 ch x 64 max-min-pooled derivative samples)
    conv   8 ch, kernel 8, stride 2, 32 positions   -> upper array half
    relu + >>2 requantise                            (SIMD CPUs)
    fc1    256 -> 123, split into two 128-input column blocks -> lower half
    partial-sum add + relu + >>2                     (SIMD CPUs)
    fc2    123 -> 10                                 -> lower half, cols 246..255
    avg-pool 5+5 -> 2 class scores                   (SIMD CPUs)

Every array pass is *physically* the same operation — one integration cycle
of a 256x256 synapse-array half — so each layer's logical weights are packed
into a 256x256 physical weight matrix ("mapping", mirrored by
rust/src/nn/mapping.rs), and the forward pass is three calls of the L1
kernel.  The rust engine executes the identical three passes against
``artifacts/vmm.hlo.txt``.

Two execution flavours:
  * ``forward_hw``      — hardware semantics (quantised, noisy), built on the
                          pallas kernel / ref oracle; used for AOT export and
                          the hardware-in-the-loop forward pass.
  * ``forward_trainable`` — same maths with straight-through estimators, used
                          for the backward pass during HIL training.
  * ``forward_mock``    — float "mock mode" (paper §II-D) without quantisation
                          or noise; prototyping baseline + ablation.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import hwmodel as hw
from .kernels import ref
from .kernels.analog_vmm import analog_vmm


# --- logical -> physical weight mapping (mirrors rust/src/nn/mapping.rs) ---

def _conv_placement():
    """Index arrays for the Toeplitz conv placement (computed once).

    Returns (rows, cols, (o, c, t)) such that
    ``physical[rows, cols] = wc[o, c, t]``.
    """
    rows, cols, oo, cc, tt = [], [], [], [], []
    for p in range(hw.CONV_POSITIONS):
        start = p * hw.CONV_STRIDE - hw.CONV_PAD
        for o in range(hw.CONV_CHANNELS):
            col = p * hw.CONV_CHANNELS + o
            for c in range(hw.ECG_CHANNELS):
                for t in range(hw.CONV_KERNEL):
                    ti = start + t
                    if 0 <= ti < hw.POOLED_LEN:
                        rows.append(c * hw.POOLED_LEN + ti)
                        cols.append(col)
                        oo.append(o)
                        cc.append(c)
                        tt.append(t)
    idx = tuple(np.asarray(a, np.int32) for a in (rows, cols, oo, cc, tt))
    return idx


_CONV_IDX = _conv_placement()


def pack_conv(wc):
    """Toeplitz placement of the conv kernel, replicated 32x (paper Fig 6).

    wc: [C_OUT, C_IN, K] float/int weights.
    Returns [K_LOGICAL, N_COLS] physical matrix for the upper array half.
    Input layout on rows: row = ch * POOLED_LEN + t  (t pooled time index).
    Column layout: col = position * C_OUT + out_channel.
    """
    rows, cols, oo, cc, tt = _CONV_IDX
    m = jnp.zeros((hw.K_LOGICAL, hw.N_COLS), wc.dtype)
    return m.at[rows, cols].set(wc[oo, cc, tt])


def pack_conv_np(wc):
    """Numpy fast-path of :func:`pack_conv` (used at export time)."""
    rows, cols, oo, cc, tt = _CONV_IDX
    m = np.zeros((hw.K_LOGICAL, hw.N_COLS), np.float32)
    m[rows, cols] = np.asarray(wc)[oo, cc, tt]
    return m


def pack_fc1(w1):
    """fc1 256->123 as two side-by-side 128-input column blocks (Fig 6).

    Rows 0..127 (event group A) drive columns 0..122 with w1[:128];
    rows 128..255 (event group B, synapse address matching) drive columns
    123..245 with w1[128:].  Partial sums are added digitally.
    """
    m = jnp.zeros((hw.K_LOGICAL, hw.N_COLS), w1.dtype)
    m = m.at[0:hw.K_SIGNED, 0:hw.FC1_OUT].set(w1[0:hw.K_SIGNED])
    m = m.at[hw.K_SIGNED:hw.K_LOGICAL, hw.FC1_OUT:2 * hw.FC1_OUT].set(
        w1[hw.K_SIGNED:hw.K_LOGICAL])
    return m


def pack_fc2(w2):
    """fc2 123->10 on the lower half's right-most columns (246..255)."""
    m = jnp.zeros((hw.K_LOGICAL, hw.N_COLS), w2.dtype)
    m = m.at[0:hw.FC1_OUT, 2 * hw.FC1_OUT:2 * hw.FC1_OUT + hw.FC2_OUT].set(w2)
    return m


def init_params(key):
    """Float master weights in [-1, 1]."""
    k1, k2, k3 = jax.random.split(key, 3)
    fan_c = hw.ECG_CHANNELS * hw.CONV_KERNEL
    wc = jax.random.normal(k1, (hw.CONV_CHANNELS, hw.ECG_CHANNELS,
                                hw.CONV_KERNEL)) / np.sqrt(fan_c)
    w1 = jax.random.normal(k2, (hw.K_LOGICAL, hw.FC1_OUT)) / np.sqrt(hw.CONV_OUT)
    w2 = jax.random.normal(k3, (hw.FC1_OUT, hw.FC2_OUT)) / np.sqrt(hw.FC1_OUT)
    return {"wc": wc, "w1": w1, "w2": w2}


def default_calib(key=None, nominal=False):
    """Per-column analog calibration state for both array halves.

    On the real system this comes from the calibration routines (Weis et al.);
    here the fixed-pattern realisation is drawn once and stored with the
    weights.  ``nominal=True`` gives the ideal (gain 1, offset 0) substrate.
    """
    if nominal or key is None:
        gain = jnp.ones((2, hw.N_COLS))
        offset = jnp.zeros((2, hw.N_COLS))
    else:
        kg, ko = jax.random.split(key)
        gain = 1.0 + hw.GAIN_FPN_SIGMA * jax.random.normal(kg, (2, hw.N_COLS))
        offset = hw.OFFSET_FPN_SIGMA * jax.random.normal(ko, (2, hw.N_COLS))
    return {"gain": gain, "offset": offset}


# Per-layer amplification ("scale"): chosen so pre-ADC voltages use the 8-bit
# range without saturating; fixed after calibration (see train.calibrate_scales).
DEFAULT_SCALES = (0.045, 0.02, 0.06)


# --- straight-through helpers ----------------------------------------------

def _ste_round(x):
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _ste_clip(x, lo, hi):
    return x + jax.lax.stop_gradient(jnp.clip(x, lo, hi) - x)


def quantize_weights_ste(w):
    return _ste_round(_ste_clip(w, -1.0, 1.0) * hw.W_MAX)


# --- forward passes ---------------------------------------------------------

def _simd_partial_relu_requant(adc2):
    """SIMD-CPU digital step between fc1 and fc2 (partial add + relu + >>2)."""
    partial = adc2[0:hw.FC1_OUT] + adc2[hw.FC1_OUT:2 * hw.FC1_OUT]
    return ref.requantize(partial)


def _simd_pool(adc3):
    """SIMD-CPU average pooling of the 10 output neurons to 2 class scores."""
    outs = adc3[2 * hw.FC1_OUT:2 * hw.FC1_OUT + hw.FC2_OUT]
    return outs.reshape(hw.N_CLASSES, hw.POOL_GROUP).mean(axis=1)


def forward_hw(params_q, act, calib, noise, scales=DEFAULT_SCALES,
               vmm=analog_vmm):
    """Hardware-semantics forward pass: three physical array passes.

    params_q: dict of *quantised* weights (integers on the 6-bit grid).
    act:      f32[128] 5-bit activations from the preprocessing chain.
    calib:    {"gain": [2, N], "offset": [2, N]} per array half (0=upper).
    noise:    f32[3, N] temporal-noise realisation per pass.
    vmm:      kernel implementation (analog_vmm or ref.analog_vmm_ref).
    Returns f32[2] class scores (average-pooled ADC counts).
    """
    wm_c = pack_conv(params_q["wc"])
    wm_1 = pack_fc1(params_q["w1"])
    wm_2 = pack_fc2(params_q["w2"])

    x0 = jnp.zeros(hw.K_LOGICAL).at[0:hw.MODEL_IN].set(act)
    adc1 = vmm(x0, wm_c, calib["gain"][0], calib["offset"][0], noise[0],
               jnp.float32(scales[0]))
    a1 = ref.requantize(adc1)                         # SIMD: relu + >>2

    adc2 = vmm(a1, wm_1, calib["gain"][1], calib["offset"][1], noise[1],
               jnp.float32(scales[1]))
    a2 = _simd_partial_relu_requant(adc2)             # SIMD: add + relu + >>2

    x2 = jnp.zeros(hw.K_LOGICAL).at[0:hw.FC1_OUT].set(a2)
    adc3 = vmm(x2, wm_2, calib["gain"][1], calib["offset"][1], noise[2],
               jnp.float32(scales[2]))
    return _simd_pool(adc3)                           # SIMD: avg-pool 5+5


def _vmm_ste(x, w, gain, offset, noise, scale):
    """Differentiable analog VMM (straight-through quantisation/saturation)."""
    acc = jnp.dot(x, w)
    v = scale * gain * acc + offset + noise
    v = _ste_clip(v, -hw.MEMBRANE_CLIP, hw.MEMBRANE_CLIP)
    return _ste_clip(_ste_round(v), float(hw.ADC_MIN), float(hw.ADC_MAX))


def _ste_floor(x):
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


def _requant_ste(adc, shift=hw.RELU_SHIFT):
    relu = jnp.maximum(adc, 0.0)
    return _ste_clip(_ste_floor(relu / float(1 << shift)),
                     0.0, float(hw.X_MAX))


def forward_trainable(params, act, calib, noise, scales=DEFAULT_SCALES):
    """HIL-training forward: identical maths, straight-through gradients."""
    q = {k: quantize_weights_ste(v) for k, v in params.items()}
    wm_c = pack_conv(q["wc"])
    wm_1 = pack_fc1(q["w1"])
    wm_2 = pack_fc2(q["w2"])

    x0 = jnp.zeros(hw.K_LOGICAL).at[0:hw.MODEL_IN].set(act)
    adc1 = _vmm_ste(x0, wm_c, calib["gain"][0], calib["offset"][0], noise[0],
                    scales[0])
    a1 = _requant_ste(adc1)
    adc2 = _vmm_ste(a1, wm_1, calib["gain"][1], calib["offset"][1], noise[1],
                    scales[1])
    partial = adc2[0:hw.FC1_OUT] + adc2[hw.FC1_OUT:2 * hw.FC1_OUT]
    a2 = _requant_ste(partial)
    x2 = jnp.zeros(hw.K_LOGICAL).at[0:hw.FC1_OUT].set(a2)
    adc3 = _vmm_ste(x2, wm_2, calib["gain"][1], calib["offset"][1], noise[2],
                    scales[2])
    outs = adc3[2 * hw.FC1_OUT:2 * hw.FC1_OUT + hw.FC2_OUT]
    # Max-pool during training for robustness (paper §III-B), avg at inference.
    return outs.reshape(hw.N_CLASSES, hw.POOL_GROUP).max(axis=1)


def forward_mock(params, act):
    """Float mock mode: no quantisation, no noise, ideal substrate."""
    wm_c = pack_conv(params["wc"])
    wm_1 = pack_fc1(params["w1"])
    wm_2 = pack_fc2(params["w2"])
    x0 = jnp.zeros(hw.K_LOGICAL).at[0:hw.MODEL_IN].set(act)
    h1 = jnp.maximum(jnp.dot(x0, wm_c), 0.0)
    h2p = jnp.dot(h1, wm_1)
    h2 = jnp.maximum(h2p[0:hw.FC1_OUT] + h2p[hw.FC1_OUT:2 * hw.FC1_OUT], 0.0)
    x2 = jnp.zeros(hw.K_LOGICAL).at[0:hw.FC1_OUT].set(h2)
    h3 = jnp.dot(x2, wm_2)
    outs = h3[2 * hw.FC1_OUT:2 * hw.FC1_OUT + hw.FC2_OUT]
    return outs.reshape(hw.N_CLASSES, hw.POOL_GROUP).mean(axis=1)


def fused_inference_fn(params_q_np, calib_np, scales=DEFAULT_SCALES):
    """Fused full-network closure with baked weights (python-side testing
    only — NOT exportable: ``as_hlo_text`` elides large constants, see
    ``fused_inference_param_fn`` for the AOT artifact)."""
    wq = {k: jnp.asarray(v) for k, v in params_q_np.items()}
    calib = {k: jnp.asarray(v) for k, v in calib_np.items()}
    zero = jnp.zeros((3, hw.N_COLS))

    def fn(act):
        return (forward_hw(wq, act, calib, zero, scales),)

    return fn


def fused_inference_param_fn(scales=DEFAULT_SCALES):
    """The fused AOT artifact ``model.hlo.txt``: weights as *parameters*.

    HLO text elides constants larger than a few elements (``{...}``), so the
    physical weight matrices cannot be baked into the interchange text; the
    rust side passes the packed matrices it loads from ``weights.json``.
    Noise is zero — the rust engine injects noise only on the 3-pass
    ``vmm.hlo`` path.

    Signature: (act f32[128], wm_c f32[256,256], wm_1 f32[256,256],
                wm_2 f32[256,256], gain f32[2,256], offset f32[2,256])
               -> (scores f32[2],)
    """
    zero = jnp.zeros(hw.N_COLS)
    s1, s2, s3 = (jnp.float32(s) for s in scales)

    def fn(act, wm_c, wm_1, wm_2, gain, offset):
        x0 = jnp.zeros(hw.K_LOGICAL).at[0:hw.MODEL_IN].set(act)
        adc1 = analog_vmm(x0, wm_c, gain[0], offset[0], zero, s1)
        a1 = ref.requantize(adc1)
        adc2 = analog_vmm(a1, wm_1, gain[1], offset[1], zero, s2)
        a2 = _simd_partial_relu_requant(adc2)
        x2 = jnp.zeros(hw.K_LOGICAL).at[0:hw.FC1_OUT].set(a2)
        adc3 = analog_vmm(x2, wm_2, gain[1], offset[1], zero, s3)
        return (_simd_pool(adc3),)

    return fn


def vmm_pass_fn():
    """Signature for the reusable single-pass artifact ``vmm.hlo.txt``.

    (x f32[256], w f32[256,256], gain f32[256], offset f32[256],
     noise f32[256], scale f32[]) -> (adc f32[256],)
    One physical integration cycle; the rust engine calls it three times per
    inference with the packed per-layer matrices, exactly like the chip
    reuses its array halves.
    """
    def fn(x, w, gain, offset, noise, scale):
        return (analog_vmm(x, w, gain, offset, noise, scale),)

    return fn
