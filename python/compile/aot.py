"""AOT exporter: lower the L2 model (wrapping the L1 pallas kernel) to HLO.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exports into ``--out`` (default ../artifacts):

  vmm.hlo.txt       one physical synapse-array pass
                    (x[256], w[256,256], gain[256], offset[256], noise[256],
                     scale[]) -> (adc[256],)
                    — executed three times per inference by the rust engine.
  model.hlo.txt     fused full network with trained weights baked in
                    (act[128]) -> (scores[2],) — mock/validation path.
  manifest.json     shapes, hardware constants, artifact hashes; the rust
                    test-suite cross-checks these against asic/consts.rs.
  vmm_testvec.json  deterministic input/output pairs computed through the
                    pallas kernel — the rust integration tests replay them
                    through the compiled artifact and compare bit-exactly.
  model_testvec.json  act -> scores pairs for the fused artifact + the
                    3-pass composition (they must agree: noise = 0).

Run ``compile.train`` first; this module refuses to export without trained
weights (the fused artifact bakes them in).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import hwmodel as hw
from . import model
from .kernels import ref
from .kernels.analog_vmm import analog_vmm


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    Guards against constant elision: ``as_hlo_text`` prints large literals
    as ``{...}``, which the text parser on the rust side would silently turn
    into garbage — any tensor bigger than a few elements must therefore be a
    *parameter* of the exported function, never a baked constant.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    text = comp.as_hlo_text()
    assert "..." not in text, (
        "HLO text contains elided constants; bake-in is not supported — "
        "pass large tensors as parameters instead")
    return text


def _sha256(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        h.update(f.read())
    return h.hexdigest()


def load_weights(out_dir):
    path = os.path.join(out_dir, "weights.json")
    if not os.path.exists(path):
        raise SystemExit(
            f"{path} missing — run `python -m compile.train --out {out_dir}` "
            "first (make artifacts does this).")
    with open(path) as f:
        w = json.load(f)
    pq = {k: np.asarray(w[k], np.float32) for k in ("wc", "w1", "w2")}
    calib = {"gain": np.asarray(w["gain"], np.float32),
             "offset": np.asarray(w["offset"], np.float32)}
    return w, pq, calib


def export_vmm(out_dir):
    """Lower the single-pass pallas kernel with runtime-supplied weights."""
    spec_x = jax.ShapeDtypeStruct((hw.K_LOGICAL,), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((hw.K_LOGICAL, hw.N_COLS), jnp.float32)
    spec_v = jax.ShapeDtypeStruct((hw.N_COLS,), jnp.float32)
    spec_s = jax.ShapeDtypeStruct((), jnp.float32)
    fn = model.vmm_pass_fn()
    lowered = jax.jit(fn).lower(spec_x, spec_w, spec_v, spec_v, spec_v, spec_s)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "vmm.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)")
    return path


def export_model(out_dir, pq, calib, scales):
    """Lower the fused network; weights are runtime parameters (HLO text
    elides large constants, so they cannot be baked in)."""
    fn = model.fused_inference_param_fn(tuple(scales))
    spec_act = jax.ShapeDtypeStruct((hw.MODEL_IN,), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((hw.K_LOGICAL, hw.N_COLS), jnp.float32)
    spec_cal = jax.ShapeDtypeStruct((2, hw.N_COLS), jnp.float32)
    lowered = jax.jit(fn).lower(spec_act, spec_w, spec_w, spec_w, spec_cal,
                                spec_cal)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "model.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)")
    return path


def export_vmm_testvec(out_dir, n_cases=4, seed=7):
    """Deterministic kernel-level test vectors for the rust integration tests."""
    rng = np.random.default_rng(seed)
    cases = []
    for i in range(n_cases):
        x = rng.integers(0, hw.X_MAX + 1, hw.K_LOGICAL).astype(np.float32)
        w = rng.integers(-hw.W_MAX, hw.W_MAX + 1,
                         (hw.K_LOGICAL, hw.N_COLS)).astype(np.float32)
        gain = (1 + hw.GAIN_FPN_SIGMA * rng.standard_normal(hw.N_COLS)
                ).astype(np.float32)
        offset = (hw.OFFSET_FPN_SIGMA * rng.standard_normal(hw.N_COLS)
                  ).astype(np.float32)
        noise = (hw.NOISE_SIGMA * rng.standard_normal(hw.N_COLS)
                 ).astype(np.float32)
        scale = np.float32(0.002 + 0.03 * rng.random())
        out = np.asarray(analog_vmm(jnp.asarray(x), jnp.asarray(w),
                                    jnp.asarray(gain), jnp.asarray(offset),
                                    jnp.asarray(noise), jnp.asarray(scale)))
        cases.append({
            "x": x.tolist(), "w": w.reshape(-1).tolist(),
            "gain": gain.tolist(), "offset": offset.tolist(),
            "noise": noise.tolist(), "scale": float(scale),
            "expected": out.tolist(),
        })
    path = os.path.join(out_dir, "vmm_testvec.json")
    with open(path, "w") as f:
        json.dump({"k": hw.K_LOGICAL, "n": hw.N_COLS, "cases": cases}, f)
    print(f"[aot] wrote {path} ({n_cases} cases)")
    return path


def export_model_testvec(out_dir, pq, calib, scales, n_cases=8, seed=13):
    """act -> scores pairs: fused artifact must equal 3-pass composition."""
    from . import data
    pq_j = {k: jnp.asarray(v) for k, v in pq.items()}
    calib_j = {k: jnp.asarray(v) for k, v in calib.items()}
    zero = jnp.zeros((3, hw.N_COLS))
    cases = []
    for i in range(n_cases):
        u12, label = data.generate_trace(900_000 + i * 31, i % 2 == 1)
        act = data.preprocess(u12)
        scores = np.asarray(model.forward_hw(
            pq_j, jnp.asarray(act), calib_j, zero, tuple(scales),
            vmm=ref.analog_vmm_ref))
        cases.append({"act": act.tolist(), "label": label,
                      "scores": scores.tolist()})
    path = os.path.join(out_dir, "model_testvec.json")
    with open(path, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"[aot] wrote {path} ({n_cases} cases)")
    return path


def export_manifest(out_dir, files, weights_meta):
    manifest = {
        "format": "bss2-artifacts-v1",
        "hw": {
            "k_logical": hw.K_LOGICAL, "k_signed": hw.K_SIGNED,
            "n_cols": hw.N_COLS, "w_max": hw.W_MAX, "x_max": hw.X_MAX,
            "adc_min": hw.ADC_MIN, "adc_max": hw.ADC_MAX,
            "membrane_clip": hw.MEMBRANE_CLIP, "relu_shift": hw.RELU_SHIFT,
            "preproc_shift": hw.PREPROC_SHIFT,
            "noise_sigma": hw.NOISE_SIGMA,
            "event_period_ns": hw.EVENT_PERIOD_NS,
            "integration_cycle_us": hw.INTEGRATION_CYCLE_US,
            "ecg_window": hw.ECG_WINDOW, "ecg_channels": hw.ECG_CHANNELS,
            "pool_window": hw.POOL_WINDOW, "model_in": hw.MODEL_IN,
            "conv": {"kernel": hw.CONV_KERNEL, "stride": hw.CONV_STRIDE,
                     "channels": hw.CONV_CHANNELS,
                     "positions": hw.CONV_POSITIONS, "pad": hw.CONV_PAD},
            "fc1_out": hw.FC1_OUT, "fc2_out": hw.FC2_OUT,
            "pool_group": hw.POOL_GROUP,
            "macs": {"conv": hw.MACS_CONV, "fc1": hw.MACS_FC1,
                     "fc2": hw.MACS_FC2, "total": hw.MACS_TOTAL},
            "ops_total": hw.OPS_TOTAL,
        },
        "scales": weights_meta["scales"],
        "metrics": weights_meta.get("metrics", {}),
        "files": {os.path.basename(p): _sha256(p) for p in files},
    }
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    weights_meta, pq, calib = load_weights(args.out)
    scales = weights_meta["scales"]

    files = [
        export_vmm(args.out),
        export_model(args.out, pq, calib, scales),
        export_vmm_testvec(args.out),
        export_model_testvec(args.out, pq, calib, scales),
        os.path.join(args.out, "weights.json"),
    ]
    export_manifest(args.out, files, weights_meta)


if __name__ == "__main__":
    main()
