"""Shared hardware-model constants for the BrainScaleS-2 analog network core.

These constants define the *computationally observable* behaviour of one
synapse-array half of the BSS-2 ASIC in its rate-based (vector-matrix
multiplication) operation mode, as described in §II-A of the paper:

  * 256 physical synapse rows per array half. Signed weights are realised by
    splitting each logical input onto an excitatory and an inhibitory row
    (paper Fig 4: separate inputs A/B per neuron), so 128 *signed* inputs per
    half.  Synapse-level address matching additionally allows a second event
    group to target a disjoint column block within the same integration cycle
    (used by the paper's fc1 "dotted part", Fig 6) — the logical VMM therefore
    exposes K = 256 logical signed inputs.
  * 256 neuron columns per array half (512 neurons on the chip).
  * 6-bit weights (|w| <= 63), 5-bit input activations (0..31) encoded as
    pulse lengths.
  * Analog accumulation on the membrane capacitance, subject to per-column
    gain/offset fixed-pattern variation, temporal noise and saturation.
  * Parallel 8-bit ADC readout.  The ADC offset can be aligned with V_reset
    to perform a ReLU during conversion (paper §II-A); for the ECG model the
    paper instead reads signed values and performs ReLUs digitally in the
    SIMD CPUs (paper Fig 6 caption), which is our default.

The identical constants are mirrored on the rust side in
``rust/src/asic/consts.rs``; ``aot.py`` writes them into
``artifacts/manifest.json`` and the rust test-suite cross-checks them.
"""

# --- Array geometry -------------------------------------------------------
K_LOGICAL = 256     # logical signed inputs per array half (address-matched)
K_SIGNED = 128      # signed inputs that map 1:1 onto physical row pairs
N_COLS = 256        # neuron columns per array half
N_HALVES = 2        # two array halves (top: conv, bottom: fc1+fc2)
N_QUADRANTS = 4     # 4 quadrants of 128 neurons x (128x256) synapses

# --- Resolutions ----------------------------------------------------------
W_MAX = 63          # 6-bit weight magnitude
X_MAX = 31          # 5-bit input activation (pulse length)
ADC_MIN = -128      # signed 8-bit ADC counts relative to V_reset
ADC_MAX = 127
MEMBRANE_CLIP = 160.0   # membrane saturation in ADC-LSB units (beyond ADC range)

# --- Analog non-idealities (calibration-time parameters) ------------------
GAIN_FPN_SIGMA = 0.06    # per-column multiplicative fixed-pattern variation
OFFSET_FPN_SIGMA = 2.0   # per-column additive offset [LSB]
NOISE_SIGMA = 2.0        # temporal (trial-to-trial) noise [LSB]

# --- Requantisation (SIMD CPU, §II-A "bitwise right-shifts") ---------------
RELU_SHIFT = 2           # adc>>2: 127 -> 31, back to 5-bit activations

# --- Timing model (paper §II-A / Eq. 1-2) ----------------------------------
EVENT_PERIOD_NS = 8.0          # back-to-back synaptic input period
INTEGRATION_CYCLE_US = 5.0     # full VMM cycle incl. membrane reset
LVDS_LINKS = 5                 # links routed to the FPGA (of 8 on the ASIC)
LVDS_GBPS = 2.0                # per-link bandwidth

# --- Area model (paper Eq. 3) ----------------------------------------------
SYNAPSE_UM2 = 8.0 * 12.0       # synapse area
DIE_MM2 = 32.0                 # BSS-2 die size

# --- ECG model hyperparameters (paper Fig 6 instantiation, DESIGN.md §3) ---
ECG_FS_HZ = 150.0        # synthetic trace sample rate
ECG_WINDOW = 2048        # classification window per channel (~13.65 s)
ECG_CHANNELS = 2
POOL_WINDOW = 32         # max-min pooling window (paper Fig 7)
PREPROC_SHIFT = 5        # 12-bit pooled derivative -> 5-bit activations
POOLED_LEN = ECG_WINDOW // POOL_WINDOW   # 64 per channel
MODEL_IN = POOLED_LEN * ECG_CHANNELS     # 128 5-bit inputs

CONV_KERNEL = 8          # conv taps along time
CONV_STRIDE = 2
CONV_CHANNELS = 8        # output feature channels
CONV_POSITIONS = 32      # padded output positions (32 replicas, paper Fig 6)
CONV_PAD = 3             # left zero-padding
CONV_OUT = CONV_POSITIONS * CONV_CHANNELS   # 256

FC1_OUT = 123            # hidden neurons (paper Fig 6)
FC2_OUT = 10             # output neurons, avg-pooled 5+5 -> 2 classes
N_CLASSES = 2
POOL_GROUP = FC2_OUT // N_CLASSES

# MAC counts (DESIGN.md §3; paper Table 1 reports 132 kOp for its unpublished
# exact window sizes — we report ours and scale rates accordingly)
MACS_CONV = CONV_OUT * CONV_KERNEL * ECG_CHANNELS      # 4096
MACS_FC1 = CONV_OUT * FC1_OUT                          # 31488
MACS_FC2 = FC1_OUT * FC2_OUT                           # 1230
MACS_TOTAL = MACS_CONV + MACS_FC1 + MACS_FC2           # 36814
OPS_TOTAL = 2 * MACS_TOTAL                             # mult+add counted separately
