"""Synthetic two-channel ECG dataset (substitute for the private BMBF set).

The paper's dataset (16 000 two-channel, 12-bit traces from a single patient
group, recorded at consumer-wearable quality) is not publicly available
(paper footnote 1).  Per the substitution rule we generate synthetic traces
that reproduce the *class-defining statistics* the classifier must exploit:

  sinus rhythm:  regular RR intervals with respiratory sinus arrhythmia,
                 P-QRS-T morphology (sum-of-Gaussians beats).
  atrial fib.:   irregularly-irregular RR intervals (i.i.d. heavy-jitter),
                 absent P-waves, fibrillatory 4-9 Hz baseline waves.

Both classes share baseline wander, white sensor noise, occasional electrode
artifacts and per-trace amplitude variation, so the task is non-trivial: the
``difficulty`` parameter widens the class overlap (borderline paroxysmal
cases) and is calibrated such that the trained hardware model lands in the
paper's accuracy regime (detection ~94 %, false positives ~14 %, Table 1).

Traces are quantised to 12 bit (paper §II-C: "an ECG trace composed of 12-bit
values").  The identical generator is implemented in ``rust/src/ecg/gen.rs``
on the same SplitMix64 PRNG; exact-parity test vectors are exported by
``aot.py`` and cross-checked by the rust test-suite.
"""

import numpy as np

from . import hwmodel as hw

MID = 2048          # 12-bit midpoint
FULL_SCALE_MV = 2.5  # +- range mapped onto 12 bits

# Beat morphology: (center offset [fraction of RR], width [s], amplitude [mV])
# for P, Q, R, S, T waves; amplitudes for channel 0; channel 1 is a second
# lead with a different projection.
WAVES = {
    "P": (-0.18, 0.025, 0.12),
    "Q": (-0.03, 0.010, -0.14),
    "R": (0.00, 0.012, 1.10),
    "S": (0.03, 0.011, -0.22),
    "T": (0.22, 0.060, 0.28),
}
CH1_SCALE = {"P": 0.7, "Q": 1.3, "R": 0.55, "S": 1.6, "T": 0.8}


class SplitMix64:
    """Deterministic 64-bit PRNG, mirrored bit-for-bit in rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = np.uint64(seed)

    def next_u64(self) -> int:
        self.state = np.uint64((int(self.state) + 0x9E3779B97F4A7C15) & (2**64 - 1))
        z = int(self.state)
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & (2**64 - 1)
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & (2**64 - 1)
        return (z ^ (z >> 31)) & (2**64 - 1)

    def uniform(self, lo=0.0, hi=1.0) -> float:
        # 53-bit mantissa construction, identical to the rust side.
        u = self.next_u64() >> 11
        return lo + (hi - lo) * (u / float(1 << 53))

    def gauss(self) -> float:
        # Box-Muller using two uniforms; the rust side uses the same pairing.
        import math
        u1 = self.uniform(1e-12, 1.0)
        u2 = self.uniform()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def _beat_times(rng: SplitMix64, afib: bool, duration: float, difficulty: float):
    """Generate (R-peak time, per-beat amplitude factor) pairs for one trace.

    Class-defining rhythm statistics:
      * sinus: HR 55-92 bpm, respiratory sinus arrhythmia, stable amplitudes.
      * A-fib: rapid ventricular response (HR 75-135 bpm, overlapping the
        sinus band), irregularly-irregular i.i.d. RR jitter, and beat-to-beat
        R-amplitude variability (pulse deficit).
    ``difficulty`` widens the class overlap towards borderline cases.
    """
    import math
    if afib:
        hr = rng.uniform(75.0, 135.0)
    else:
        hr = rng.uniform(55.0, 92.0)
    base_rr = 60.0 / hr
    resp_f = rng.uniform(0.15, 0.35)
    resp_phase = rng.uniform(0.0, 2 * np.pi)
    beats = []
    t = rng.uniform(0.0, 0.5)
    while t < duration:
        if afib:
            # Irregularly irregular: heavy i.i.d. jitter.  Difficulty shrinks
            # the jitter towards borderline (paroxysmal-like) cases.
            jitter = 0.45 - 0.20 * difficulty * rng.uniform()
            rr = base_rr * (1.0 + jitter * (2.0 * rng.uniform() - 1.0))
            rr = max(0.30, rr)
            amp = 1.0 + 0.30 * rng.gauss()      # pulse deficit
        else:
            rsa = 0.04 * math.sin(2 * np.pi * resp_f * t + resp_phase)
            # Difficulty adds sporadic ectopic-like irregularity to sinus.
            ectopic = 0.0
            if rng.uniform() < 0.04 * difficulty:
                ectopic = 0.25 * (2.0 * rng.uniform() - 1.0)
            rr = base_rr * (1.0 + rsa + 0.015 * rng.gauss() + ectopic)
            amp = 1.0 + 0.05 * rng.gauss()
        amp = min(max(amp, 0.35), 1.8)
        beats.append((t, amp))
        t += rr
    return beats


def generate_trace(seed: int, afib: bool, n_samples: int = hw.ECG_WINDOW,
                   fs: float = hw.ECG_FS_HZ, difficulty: float = 1.0):
    """Generate one two-channel 12-bit ECG window.

    Returns (u12 array [2, n_samples], label int).
    """
    rng = SplitMix64(seed)
    duration = n_samples / fs
    tgrid = np.arange(n_samples) / fs
    sig = np.zeros((2, n_samples))

    beats = _beat_times(rng, afib, duration + 1.0, difficulty)
    amp_scale = rng.uniform(0.8, 1.2)
    p_amp = 0.0 if afib else 1.0
    # Morphology jitter per trace
    wave_jitter = {k: 1.0 + 0.15 * rng.gauss() for k in WAVES}

    for bt, bamp in beats:
        rr_local = 0.8  # nominal width scaling for wave placement
        for name, (off, width, amp) in WAVES.items():
            if name == "P" and afib:
                continue
            a0 = amp * amp_scale * bamp * wave_jitter[name] * \
                (p_amp if name == "P" else 1.0)
            c = bt + off * rr_local
            lo = max(0, int((c - 4 * width) * fs))
            hi = min(n_samples, int((c + 4 * width) * fs) + 1)
            if hi <= lo:
                continue
            tt = tgrid[lo:hi] - c
            bump = np.exp(-0.5 * (tt / width) ** 2)
            sig[0, lo:hi] += a0 * bump
            sig[1, lo:hi] += a0 * CH1_SCALE[name] * bump

    # Fibrillatory waves replace the P-wave in A-fib (4-9 Hz).
    if afib:
        f_amp = rng.uniform(0.06, 0.18)
        f_freq = rng.uniform(4.0, 9.0)
        f_phase = rng.uniform(0.0, 2 * np.pi)
        fib = f_amp * np.sin(2 * np.pi * f_freq * tgrid + f_phase)
        fib *= 1.0 + 0.3 * np.sin(2 * np.pi * 0.9 * tgrid + f_phase * 0.7)
        sig[0] += fib
        sig[1] += 0.8 * fib

    # Baseline wander (both classes).
    bw_amp = rng.uniform(0.05, 0.30)
    bw_f = rng.uniform(0.15, 0.45)
    bw_phase = rng.uniform(0.0, 2 * np.pi)
    wander = bw_amp * np.sin(2 * np.pi * bw_f * tgrid + bw_phase)
    sig[0] += wander
    sig[1] += 0.9 * wander

    # Sensor noise (consumer-wearable quality) + occasional artifact spike.
    noise_sigma = rng.uniform(0.015, 0.035) * (1.0 + 0.5 * difficulty)
    for ch in range(2):
        nvec = np.array([rng.gauss() for _ in range(n_samples // 8)])
        sig[ch] += noise_sigma * np.repeat(nvec, 8)[:n_samples]
    if rng.uniform() < 0.15:
        pos = int(rng.uniform(0.0, n_samples - 40))
        sig[:, pos:pos + 20] += rng.uniform(-0.8, 0.8)

    # 12-bit quantisation.
    u12 = np.clip(np.round(sig / FULL_SCALE_MV * MID) + MID, 0, 4095)
    return u12.astype(np.uint16), int(afib)


def generate_dataset(n: int, seed: int = 1234, afib_fraction: float = 0.5,
                     difficulty: float = 1.0):
    """Generate ``n`` traces; returns (u12 [n, 2, W], labels [n])."""
    xs = np.zeros((n, hw.ECG_CHANNELS, hw.ECG_WINDOW), np.uint16)
    ys = np.zeros(n, np.int32)
    for i in range(n):
        afib = (i % 2 == 1) if afib_fraction == 0.5 else \
            (SplitMix64(seed * 7919 + i).uniform() < afib_fraction)
        xs[i], ys[i] = generate_trace(seed * 1_000_003 + i * 97, afib,
                                      difficulty=difficulty)
    return xs, ys


# --- FPGA preprocessing chain (paper Fig 7), software mirror ---------------

def preprocess(u12):
    """Mirror of the FPGA preprocessing chain (rust/src/fpga/preprocess.rs).

    u12: uint16 [2, W] raw samples  ->  f32 [MODEL_IN] 5-bit activations.

    1. discrete derivative (suppresses baseline wander),
    2. max-min pooling over POOL_WINDOW samples (rate reduction, positive),
    3. 5-bit quantisation.
    """
    x = u12.astype(np.int32)
    d = np.diff(x, axis=1, prepend=x[:, :1])            # [2, W]
    d = d.reshape(2, hw.POOLED_LEN, hw.POOL_WINDOW)
    pooled = d.max(axis=2) - d.min(axis=2)              # [2, 64], >= 0
    # 5-bit quantisation: fixed right-shift, matching the FPGA barrel shifter.
    # 12-bit derivative range / 2^SHIFT -> clip to 31.
    act = np.clip(pooled >> hw.PREPROC_SHIFT, 0, hw.X_MAX)
    return act.reshape(-1).astype(np.float32)           # [128]


def preprocess_batch(u12s):
    return np.stack([preprocess(t) for t in u12s])
