"""Hardware-in-the-loop-style training of the ECG classifier (paper §III-B).

Reproduces the hxtorch training contract: the *forward* pass runs through the
hardware model (quantised weights, analog gain/offset fixed pattern, temporal
noise, saturating membranes, 8-bit ADC) while the *backward* pass is computed
in software via straight-through estimators.  Max-pooling over the 5 output
neurons per class during training, average-pooling at inference (paper
§III-B).  Early stopping on the validation metric.

Outputs (all consumed by the rust side / the AOT exporter):
  artifacts/weights.json        6-bit weights + calibration + scales + metrics
  artifacts/fig8_training.csv   per-epoch train/val metrics (paper Fig 8)
  artifacts/ecg_test.bin        500-trace held-out test set (12-bit, binary)
  artifacts/ecg_cal.bin         small calibration set for rust smoke tests

Run: ``cd python && python -m compile.train --out ../artifacts``
Environment knobs: BSS2_TRAIN_TRACES, BSS2_EPOCHS, BSS2_SEED (see --help).
"""

import argparse
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from . import hwmodel as hw
from . import model
from .kernels import ref

LOGIT_TEMP = 16.0   # ADC counts per softmax unit


# --- Adam (hand-rolled; optax is not available offline) ---------------------

def adam_init(params):
    z = lambda: jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z(), "v": z(), "t": 0}


def adam_update(params, grads, state, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) /
        (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


# --- scale calibration -------------------------------------------------------

def calibrate_scales(params, acts, calib, target=100.0, pct=99.0):
    """Pick per-layer amplification so pre-ADC voltages span the 8-bit range.

    Mirrors the paper's per-layer "bitwise right-shift" configuration: run a
    calibration batch layer by layer and set scale such that the ``pct``-th
    percentile of |gain * acc| reaches ``target`` LSB.
    """
    q = {k: np.asarray(ref.quantize_weights(v)) for k, v in params.items()}
    wm_c = model.pack_conv_np(q["wc"])
    wm_1 = np.asarray(model.pack_fc1(jnp.asarray(q["w1"])))
    wm_2 = np.asarray(model.pack_fc2(jnp.asarray(q["w2"])))
    gain = np.asarray(calib["gain"])

    x0 = np.zeros((len(acts), hw.K_LOGICAL), np.float32)
    x0[:, 0:hw.MODEL_IN] = acts
    acc1 = (x0 @ wm_c) * gain[0]
    s1 = target / max(np.percentile(np.abs(acc1), pct), 1e-6)
    adc1 = np.clip(np.round(np.clip(s1 * acc1, -hw.MEMBRANE_CLIP,
                                    hw.MEMBRANE_CLIP)), hw.ADC_MIN, hw.ADC_MAX)
    a1 = np.clip(np.floor(np.maximum(adc1, 0) / (1 << hw.RELU_SHIFT)),
                 0, hw.X_MAX)

    acc2 = (a1 @ wm_1) * gain[1]
    s2 = target / max(np.percentile(np.abs(acc2), pct), 1e-6)
    adc2 = np.clip(np.round(np.clip(s2 * acc2, -hw.MEMBRANE_CLIP,
                                    hw.MEMBRANE_CLIP)), hw.ADC_MIN, hw.ADC_MAX)
    part = adc2[:, 0:hw.FC1_OUT] + adc2[:, hw.FC1_OUT:2 * hw.FC1_OUT]
    a2 = np.clip(np.floor(np.maximum(part, 0) / (1 << hw.RELU_SHIFT)),
                 0, hw.X_MAX)

    x2 = np.zeros((len(acts), hw.K_LOGICAL), np.float32)
    x2[:, 0:hw.FC1_OUT] = a2
    acc3 = (x2 @ wm_2) * gain[1]
    s3 = target / max(np.percentile(np.abs(acc3), pct), 1e-6)
    return (float(s1), float(s2), float(s3))


# --- training loop -----------------------------------------------------------

def make_step(calib, scales, pos_weight=1.0):
    """Class-weighted cross-entropy: ``pos_weight`` > 1 trades false
    positives for detection rate, selecting the paper's operating point
    (93.7 % detection at 14 % false positives) on the ROC curve."""
    def loss_fn(params, act, noise, label):
        scores = model.forward_trainable(params, act, calib, noise, scales)
        logits = scores / LOGIT_TEMP
        logp = jax.nn.log_softmax(logits)
        w = jnp.where(label == 1, pos_weight, 1.0)
        return -w * logp[label]

    def batch_loss(params, acts, noises, labels):
        losses = jax.vmap(loss_fn, in_axes=(None, 0, 0, 0))(
            params, acts, noises, labels)
        return losses.mean()

    @jax.jit
    def step(params, opt, acts, noises, labels):
        loss, grads = jax.value_and_grad(batch_loss)(params, acts, noises,
                                                     labels)
        params, opt = adam_update(params, grads, opt)
        return params, opt, loss

    return step, jax.jit(batch_loss)


def make_eval(calib, scales):
    """Evaluation through the *hardware* forward path (ref semantics)."""
    def fwd(params_q, act, noise):
        return model.forward_hw(params_q, act, calib, noise, scales,
                                vmm=ref.analog_vmm_ref)

    @jax.jit
    def eval_scores(params_q, acts, noises):
        return jax.vmap(fwd, in_axes=(None, 0, 0))(params_q, acts, noises)

    return eval_scores


def metrics_from_scores(scores, labels):
    """Detection rate (A-fib recall) and false-positive rate (paper Table 1)."""
    pred = np.argmax(np.asarray(scores), axis=1)
    labels = np.asarray(labels)
    pos = labels == 1
    neg = labels == 0
    det = float((pred[pos] == 1).mean()) if pos.any() else 0.0
    fp = float((pred[neg] == 1).mean()) if neg.any() else 0.0
    acc = float((pred == labels).mean())
    return det, fp, acc


# --- binary dataset export (read by rust/src/ecg/dataset.rs) -----------------

MAGIC = 0x45434731  # "ECG1"


def write_ecg_bin(path, traces, labels):
    """Format: u32 magic, u32 n, u32 channels, u32 window; per trace:
    u8 label + channels*window u16 LE samples."""
    n, ch, w = traces.shape
    with open(path, "wb") as f:
        f.write(struct.pack("<IIII", MAGIC, n, ch, w))
        for i in range(n):
            f.write(struct.pack("<B", int(labels[i])))
            f.write(traces[i].astype("<u2").tobytes())


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--traces", type=int,
                    default=int(os.environ.get("BSS2_TRAIN_TRACES", "3000")))
    ap.add_argument("--epochs", type=int,
                    default=int(os.environ.get("BSS2_EPOCHS", "40")))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("BSS2_SEED", "42")))
    ap.add_argument("--difficulty", type=float, default=1.0)
    ap.add_argument("--patience", type=int, default=8)
    ap.add_argument("--fc1", type=int, default=hw.FC1_OUT,
                    help="hidden width (sweeps use non-default; export skipped)")
    ap.add_argument("--pos-weight", type=float,
                    default=float(os.environ.get("BSS2_POS_WEIGHT", "1.3")))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    t0 = time.time()
    n_total = args.traces + 1000   # + val 500 + test 500
    print(f"[train] generating {n_total} synthetic ECG traces ...")
    xs, ys = data.generate_dataset(n_total, seed=args.seed,
                                   difficulty=args.difficulty)
    acts = data.preprocess_batch(xs).astype(np.float32)
    n_tr = args.traces
    tr_a, tr_y = acts[:n_tr], ys[:n_tr]
    va_a, va_y = acts[n_tr:n_tr + 500], ys[n_tr:n_tr + 500]
    te_a, te_y = acts[n_tr + 500:], ys[n_tr + 500:]
    te_x = xs[n_tr + 500:]
    print(f"[train] dataset ready ({time.time() - t0:.1f}s); "
          f"train={n_tr} val=500 test=500, afib fraction={ys.mean():.2f}")

    key = jax.random.PRNGKey(args.seed)
    kp, kc, kn = jax.random.split(key, 3)
    params = model.init_params(kp)
    calib = model.default_calib(kc)
    scales = calibrate_scales(params, tr_a[:512], calib)
    print(f"[train] calibrated scales: {tuple(round(s, 5) for s in scales)}")

    step, batch_loss = make_step(calib, scales, args.pos_weight)
    eval_scores = make_eval(calib, scales)
    opt = adam_init(params)

    def sample_noise(k, n):
        return hw.NOISE_SIGMA * jax.random.normal(k, (n, 3, hw.N_COLS))

    history = []
    best = {"metric": -1.0, "params": params, "epoch": -1}
    steps_per_epoch = max(1, n_tr // args.batch)
    rng = np.random.default_rng(args.seed)

    for epoch in range(args.epochs):
        order = rng.permutation(n_tr)
        ep_loss = 0.0
        for s in range(steps_per_epoch):
            idx = order[s * args.batch:(s + 1) * args.batch]
            kn, ksub = jax.random.split(kn)
            noises = sample_noise(ksub, len(idx))
            params, opt, loss = step(params, opt, jnp.asarray(tr_a[idx]),
                                     noises, jnp.asarray(tr_y[idx]))
            ep_loss += float(loss)
        ep_loss /= steps_per_epoch

        # Validation through the hardware path (quantised weights + noise).
        pq = {k: jnp.round(jnp.clip(v, -1, 1) * hw.W_MAX)
              for k, v in params.items()}
        kn, kev = jax.random.split(kn)
        va_scores = eval_scores(pq, jnp.asarray(va_a),
                                sample_noise(kev, len(va_a)))
        det, fp, acc = metrics_from_scores(va_scores, va_y)
        kn, kev = jax.random.split(kn)
        tr_scores = eval_scores(pq, jnp.asarray(tr_a[:500]),
                                sample_noise(kev, 500))
        tdet, tfp, tacc = metrics_from_scores(tr_scores, tr_y[:500])
        va_loss = float(batch_loss(params, jnp.asarray(va_a),
                                   sample_noise(kev, len(va_a)),
                                   jnp.asarray(va_y)))
        history.append((epoch, ep_loss, va_loss, tacc, acc, det, fp))
        # Select for the paper's operating point: maximise detection while
        # keeping false positives near/below the paper's 14 %.
        metric = det - 2.0 * max(0.0, fp - 0.15)
        flag = ""
        if metric > best["metric"]:
            best = {"metric": metric, "params": params, "epoch": epoch}
            flag = " *"
        print(f"[train] epoch {epoch:3d} loss={ep_loss:.4f} "
              f"val_loss={va_loss:.4f} train_acc={tacc:.3f} "
              f"val_acc={acc:.3f} det={det:.3f} fp={fp:.3f}{flag}")
        if epoch - best["epoch"] >= args.patience:
            print(f"[train] early stopping (no improvement for "
                  f"{args.patience} epochs)")
            break

    params = best["params"]
    pq = {k: np.asarray(jnp.round(jnp.clip(v, -1, 1) * hw.W_MAX), np.int32)
          for k, v in params.items()}

    # Final held-out test metrics, averaged over noise realisations (the
    # paper averages blocks of 500 inferences).
    dets, fps, accs = [], [], []
    for rep in range(5):
        kn, kev = jax.random.split(kn)
        te_scores = eval_scores({k: jnp.asarray(v, jnp.float32)
                                 for k, v in pq.items()},
                                jnp.asarray(te_a), sample_noise(kev, len(te_a)))
        d, f, a = metrics_from_scores(te_scores, te_y)
        dets.append(d)
        fps.append(f)
        accs.append(a)
    det_m, det_s = float(np.mean(dets)), float(np.std(dets))
    fp_m, fp_s = float(np.mean(fps)), float(np.std(fps))
    print(f"[train] TEST detection={det_m * 100:.1f}±{det_s * 100:.1f}% "
          f"fp={fp_m * 100:.1f}±{fp_s * 100:.1f}% acc={np.mean(accs):.3f} "
          f"(paper: 93.7±0.7% det, 14.0±1.0% fp)")

    if args.fc1 != hw.FC1_OUT:
        print("[train] non-default width: sweep mode, skipping export")
        return

    # --- exports -------------------------------------------------------------
    fig8 = os.path.join(args.out, "fig8_training.csv")
    with open(fig8, "w") as f:
        f.write("epoch,train_loss,val_loss,train_acc,val_acc,"
                "val_detection,val_false_positive\n")
        for row in history:
            f.write(",".join(f"{v:.6f}" if isinstance(v, float) else str(v)
                             for v in row) + "\n")

    weights = {
        "format": "bss2-weights-v1",
        "seed": args.seed,
        "scales": list(scales),
        "wc": pq["wc"].tolist(),
        "w1": pq["w1"].tolist(),
        "w2": pq["w2"].tolist(),
        "gain": np.asarray(calib["gain"], np.float64).round(8).tolist(),
        "offset": np.asarray(calib["offset"], np.float64).round(8).tolist(),
        "noise_sigma": hw.NOISE_SIGMA,
        "metrics": {
            "val_best_acc": best["metric"],
            "test_detection_mean": det_m, "test_detection_std": det_s,
            "test_fp_mean": fp_m, "test_fp_std": fp_s,
            "test_acc_mean": float(np.mean(accs)),
        },
    }
    with open(os.path.join(args.out, "weights.json"), "w") as f:
        json.dump(weights, f)

    write_ecg_bin(os.path.join(args.out, "ecg_test.bin"), te_x, te_y)
    cal_n = 32
    write_ecg_bin(os.path.join(args.out, "ecg_cal.bin"), xs[:cal_n], ys[:cal_n])
    print(f"[train] exported weights.json, fig8_training.csv, ecg_test.bin "
          f"({len(te_x)} traces), ecg_cal.bin ({cal_n}) to {args.out} "
          f"in {time.time() - t0:.0f}s total")


if __name__ == "__main__":
    main()
