"""L1 Pallas kernel: one integration cycle of the BSS-2 analog synapse array.

Hardware adaptation (DESIGN.md §2): the paper's compute hot-spot is an analog
crossbar — 256 synapse rows driving 256 neuron columns per array half, inputs
as 5-bit pulse lengths, 6-bit weights, charge integration on membrane
capacitances, 8-bit parallel ADC readout.  On a TPU-shaped substrate the same
schedule becomes:

  * the weight tile (K x TILE_N) is the synapse-array quadrant resident in
    VMEM (the scratchpad analogue of the synapse SRAM),
  * the activation vector is broadcast into an MXU contraction exactly like a
    pulse train is broadcast along a synapse row,
  * per-column gain/offset/noise + saturation + ADC quantisation are the
    vector-unit epilogue, fused into the kernel so membrane voltages never
    round-trip to HBM (on the ASIC they never leave the analog core).

Grid: one program per column tile; the full input vector (<= 256 values,
1 KiB) is resident per program, mirroring the event broadcast.

``interpret=True`` is mandatory: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* from the VMEM footprint and
MXU utilisation in DESIGN.md §7 / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import hwmodel as hw

# Column tile: 128 columns x 256 rows x 4 B = 128 KiB weight tile — fits VMEM
# (16 MiB/core) with generous double-buffering headroom; a multiple of the
# 128-lane vector width and of the MXU's 128x128 systolic tile.
TILE_N = 128


def _vmm_kernel(x_ref, w_ref, gain_ref, offset_ref, noise_ref, scale_ref,
                out_ref, *, relu_in_adc: bool):
    """Kernel body: one column tile of the analog array.

    x_ref:      f32[1, K]      pulse-length activations (whole vector)
    w_ref:      f32[K, TILE_N] 6-bit signed weights for this tile
    gain/offset/noise_ref: f32[1, TILE_N] per-column analog state
    scale_ref:  f32[1, 1]      per-layer amplification
    out_ref:    f32[1, TILE_N] ADC counts
    """
    x = x_ref[...]                        # [1, K]
    w = w_ref[...]                        # [K, TILE_N]
    # Charge accumulation: exact integer arithmetic carried in f32
    # (|acc| <= 31 * 63 * 256 < 2^19 << 2^24).
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)   # [1, TILE_N]
    v = scale_ref[0, 0] * gain_ref[...] * acc + offset_ref[...] + noise_ref[...]
    # Membrane saturation at the rails, then 8-bit ADC conversion.
    v = jnp.clip(v, -hw.MEMBRANE_CLIP, hw.MEMBRANE_CLIP)
    adc = jnp.round(v)
    lo = 0.0 if relu_in_adc else float(hw.ADC_MIN)
    out_ref[...] = jnp.clip(adc, lo, float(hw.ADC_MAX))


@functools.partial(jax.jit, static_argnames=("relu_in_adc",))
def analog_vmm(x, w, gain, offset, noise, scale, relu_in_adc=False):
    """Pallas analog-VMM: drop-in equivalent of ``ref.analog_vmm_ref``.

    Shapes: x f32[K], w f32[K, N], gain/offset/noise f32[N], scale f32[].
    K and N must be multiples of the lane width (K >= 1, N % TILE_N == 0 is
    *not* required — ragged tiles are padded by pallas).
    """
    k, n = w.shape
    assert x.shape == (k,), (x.shape, w.shape)
    tile = min(TILE_N, n)
    grid = (pl.cdiv(n, tile),)

    out = pl.pallas_call(
        functools.partial(_vmm_kernel, relu_in_adc=relu_in_adc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),        # x: resident
            pl.BlockSpec((k, tile), lambda i: (0, i)),     # w: column tiles
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # gain
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # offset
            pl.BlockSpec((1, tile), lambda i: (0, i)),     # noise
            pl.BlockSpec((1, 1), lambda i: (0, 0)),        # scale
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=True,   # CPU PJRT cannot run Mosaic custom-calls
    )(
        x.reshape(1, k),
        w,
        gain.reshape(1, n),
        offset.reshape(1, n),
        noise.reshape(1, n),
        jnp.asarray(scale, jnp.float32).reshape(1, 1),
    )
    return out.reshape(n)


def vmem_report(k=hw.K_LOGICAL, n=hw.N_COLS, tile=TILE_N):
    """Static VMEM footprint / MXU utilisation estimate for DESIGN.md §Perf.

    Returns a dict with bytes-per-program and the MXU occupancy of the
    contraction (how much of the 128x128 systolic tile a (1,K)x(K,tile)
    matmul keeps busy).
    """
    bytes_per = 4
    x_b = k * bytes_per
    w_b = k * tile * bytes_per
    vec_b = 4 * tile * bytes_per          # gain, offset, noise, out
    vmem = x_b + w_b + vec_b + bytes_per  # + scale
    # A rank-1 activation against the 128-wide MXU: K/128 passes, 1/128 of
    # rows busy — the analog array's advantage (full parallelism at batch 1)
    # is exactly what the MXU loses here; see EXPERIMENTS.md §Perf.
    mxu_row_util = 1.0 / 128.0
    mxu_col_util = min(tile, 128) / 128.0
    return {
        "vmem_bytes_per_program": vmem,
        "grid_programs": (n + tile - 1) // tile,
        "mxu_row_utilisation": mxu_row_util,
        "mxu_col_utilisation": mxu_col_util,
        "flops_per_program": 2 * k * tile,
    }
