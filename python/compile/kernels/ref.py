"""Pure-jnp oracle for the analog synapse-array VMM.

This is the correctness reference for the Pallas kernel in
``analog_vmm.py``.  It models one integration cycle of a BSS-2 synapse-array
half in rate-based mode (paper §II-A, Fig 4):

    acc[n]  = sum_k x[k] * w[k, n]          integer charge accumulation
    v[n]    = scale * gain[n] * acc[n] + offset[n] + noise[n]
    v[n]    = clip(v, -MEMBRANE_CLIP, +MEMBRANE_CLIP)   membrane saturation
    adc[n]  = clip(round(v[n]), ADC_MIN, ADC_MAX)       8-bit readout

``x`` are 5-bit pulse lengths (0..31), ``w`` 6-bit signed weights (-63..63).
``gain``/``offset`` are the per-column fixed-pattern calibration state;
``noise`` is the temporal noise realisation for this cycle (supplied by the
caller — on the real system it is physics, in the rust engine it comes from
the coordinator's PRNG so the HLO stays deterministic).

If ``relu_in_adc`` the ADC offset is aligned with V_reset such that negative
membrane deflections read as 0 (paper §II-A); the ECG model instead uses
signed readout with digital ReLUs in the SIMD CPUs (paper Fig 6 caption).
"""

import jax.numpy as jnp

from .. import hwmodel as hw


def analog_vmm_ref(x, w, gain, offset, noise, scale, relu_in_adc=False):
    """Reference analog VMM.

    Args:
      x:      f32[K]  input activations, integers in [0, X_MAX]
      w:      f32[K, N] signed weights, integers in [-W_MAX, W_MAX]
      gain:   f32[N]  per-column transconductance gain (calibrated ~1)
      offset: f32[N]  per-column ADC/membrane offset [LSB]
      noise:  f32[N]  temporal noise realisation [LSB]
      scale:  f32[]   per-layer amplification (right-shift analogue)
      relu_in_adc: clamp negative deflections to 0 during conversion.

    Returns:
      f32[N] ADC counts (integers in [ADC_MIN, ADC_MAX] or [0, ADC_MAX]).
    """
    acc = jnp.dot(x, w)                       # exact in f32: |acc| < 2^18
    v = scale * gain * acc + offset + noise
    v = jnp.clip(v, -hw.MEMBRANE_CLIP, hw.MEMBRANE_CLIP)
    adc = jnp.round(v)
    lo = 0.0 if relu_in_adc else float(hw.ADC_MIN)
    return jnp.clip(adc, lo, float(hw.ADC_MAX))


def quantize_weights(w_float):
    """Map float weights in [-1, 1] to the 6-bit hardware grid."""
    return jnp.round(jnp.clip(w_float, -1.0, 1.0) * hw.W_MAX)


def requantize(adc, shift=hw.RELU_SHIFT):
    """SIMD-CPU ReLU + right-shift requantisation back to 5-bit activations.

    The embedded processors apply the activation function digitally and
    convert 8-bit ADC counts to 5-bit inputs for the next layer by bitwise
    right-shift (paper §II-A).
    """
    relu = jnp.maximum(adc, 0.0)
    return jnp.clip(jnp.floor(relu / float(1 << shift)), 0.0, float(hw.X_MAX))
