# Allow `pytest python/tests/` from the repo root: the python package
# lives under python/ (build-time only; never imported at runtime).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
